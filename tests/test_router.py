"""Fault-tolerant replica router (ISSUE 9): health-gated placement,
crash-and-migrate resume, retry/backoff, and the deterministic chaos
harness.

The acceptance bar: a request migrated off a killed replica mid-decode
completes on a survivor with output token-identical to the uncontended
single-engine oracle — greedy AND seeded sampling, dense AND MoE, at
every migration offset; random interleavings of the router lifecycle
never leak pages on any replica; the same FaultPlan replayed twice
produces bit-identical outputs.
"""
import time

import numpy as np
import pytest

import jax

from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import smoke_config
from repro.models import transformer as T
from repro.serving import (
    DEAD,
    DRAINING,
    HEALTHY,
    ChaosHarness,
    EngineConfig,
    EngineOverloaded,
    FaultPlan,
    InjectNaN,
    DrainReplica,
    KillReplica,
    PagePressure,
    ReplicaSet,
    Request,
    Router,
    RouterConfig,
    SamplingParams,
    ServingEngine,
    StallSteps,
)

_PARAM_CACHE = {}


def _setup(arch):
    if arch not in _PARAM_CACHE:
        cfg = smoke_config(arch)
        _PARAM_CACHE[arch] = (cfg, T.init_params(cfg, jax.random.PRNGKey(0)))
    return _PARAM_CACHE[arch]


@pytest.fixture(scope="module")
def dense_setup():
    return _setup("glm4-9b")


_ECONF = dict(max_batch=2, max_len=64, page_size=8)


def _router(cfg, params, n=2, rconf=None, **conf):
    kw = dict(_ECONF, **conf)
    return Router(ReplicaSet.build(cfg, params, EngineConfig(**kw), n),
                  rconf or RouterConfig(placement="round_robin"))


def _oracle(cfg, params, reqs, **conf):
    """The single uncontended engine every exactness claim compares to."""
    kw = dict(_ECONF, **conf)
    eng = ServingEngine(cfg, params, EngineConfig(**kw))
    for r in reqs:
        eng.submit(r)
    eng.run()
    assert all(r.finish_reason in ("eos", "length") for r in reqs)
    return {r.uid: list(r.output) for r in reqs}


def _mk(rng, vocab, lengths, max_new=8, sampling=None):
    return [
        Request(uid=i, prompt=rng.integers(0, vocab, n).tolist(),
                max_new_tokens=max_new, sampling=sampling)
        for i, n in enumerate(lengths)
    ]


def _clone(reqs):
    return [
        Request(uid=r.uid, prompt=list(r.prompt),
                max_new_tokens=r.max_new_tokens, sampling=r.sampling)
        for r in reqs
    ]


def _assert_no_leaks(router):
    for rep in router.replicas:
        a = rep.engine.allocator
        assert a.in_use() + a.available() == a.capacity, (
            f"replica {rep.rid} ({rep.state}) leaked pages"
        )


# ---------------------------------------------------------------------------
# Tentpole (a): placement


def test_round_robin_rotates_over_healthy(dense_setup):
    cfg, params = dense_setup
    router = _router(cfg, params, n=3)
    reqs = _mk(np.random.default_rng(0), cfg.vocab, [4, 5, 6, 7, 4, 5])
    for r in reqs:
        router.submit(r)
    # uid i lands on replica i % 3 before any step runs
    by_rep = [[r.uid for r in rep.engine.queue] for rep in router.replicas]
    assert by_rep == [[0, 3], [1, 4], [2, 5]]
    router.run()
    assert all(r.finish_reason == "length" for r in reqs)


def test_least_loaded_prefers_empty_replica(dense_setup):
    cfg, params = dense_setup
    router = _router(cfg, params, n=2,
                     rconf=RouterConfig(placement="least_loaded"))
    heavy = Request(uid=0, prompt=list(range(1, 20)), max_new_tokens=30)
    light = Request(uid=1, prompt=[1, 2], max_new_tokens=2)
    router.submit(heavy)  # replica 0 (tie -> lowest rid)
    router.submit(light)  # replica 1 is strictly emptier now
    assert [r.uid for r in router.replicas[0].engine.queue] == [0]
    assert [r.uid for r in router.replicas[1].engine.queue] == [1]
    router.run()
    assert heavy.finish_reason == "length"
    assert light.finish_reason == "length"


def test_draining_and_dead_take_no_placements(dense_setup):
    cfg, params = dense_setup
    router = _router(cfg, params, n=3)
    router.drain(0)
    router.kill(1)
    reqs = _mk(np.random.default_rng(1), cfg.vocab, [4, 5], max_new=2)
    for r in reqs:
        router.submit(r)
    assert not router.replicas[0].engine.queue
    assert not router.replicas[1].engine.queue
    assert len(router.replicas[2].engine.queue) == 2
    router.run()
    assert all(r.finish_reason == "length" for r in reqs)


def test_router_rejects_unpaged_replicas(dense_setup):
    cfg, params = dense_setup
    eng = ServingEngine(cfg, params,
                        EngineConfig(max_batch=2, max_len=64, paged=False))
    with pytest.raises(ValueError, match="paged"):
        ReplicaSet([eng])


def test_router_config_validation():
    with pytest.raises(ValueError, match="placement"):
        RouterConfig(placement="random")
    with pytest.raises(ValueError, match="degraded_after"):
        RouterConfig(degraded_after=5, dead_after=2)
    with pytest.raises(ValueError, match="backoff_jitter"):
        RouterConfig(backoff_jitter=1.5)


# ---------------------------------------------------------------------------
# Tentpole (b): crash-and-migrate is oracle-exact


@pytest.mark.parametrize("arch", ["glm4-9b", "deepseek-moe-16b"])
def test_kill_migrate_greedy_exact(arch):
    """Kill a replica mid-decode: every request — including the harvested
    in-flight lanes carrying committed tokens — completes on the survivor
    token-identical to the uncontended oracle."""
    cfg, params = _setup(arch)
    # MoE smoke models have argmax knife-edges at some seeds (see
    # test_overload); pinned to a well-posed region.
    rng = np.random.default_rng(7 if arch == "glm4-9b" else 3)
    reqs = _mk(rng, cfg.vocab, [7, 5, 3, 6])
    oracle = _oracle(cfg, params, _clone(reqs))

    router = _router(cfg, params, n=2)
    for r in reqs:
        router.submit(r)
    for _ in range(4):  # prefill + a few decode steps on both replicas
        router.step()
    assert any(len(r.output) > 0 for r in reqs)
    router.kill(0)
    assert router.stats()["router_migrated"] > 0
    router.run()
    assert {r.uid: list(r.output) for r in reqs} == oracle
    assert all(r.finish_reason in ("eos", "length") for r in reqs)
    _assert_no_leaks(router)
    assert router.replicas[0].state == DEAD


@pytest.mark.parametrize("arch", ["glm4-9b", "deepseek-moe-16b"])
@pytest.mark.parametrize("kill_at", [1, 2, 3, 4, 5])
def test_migration_offset_sweep_seeded_sampling_exact(arch, kill_at):
    """The strongest exactness claim: seeded (non-greedy) sampling migrated
    at EVERY offset reproduces the oracle stream bit for bit — sampling
    keys fold (seed, position), so where a token is produced cannot change
    which token it is."""
    cfg, params = _setup(arch)
    rng = np.random.default_rng(7 if arch == "glm4-9b" else 3)
    sampling = SamplingParams(temperature=0.8, top_k=20, seed=123)
    reqs = _mk(rng, cfg.vocab, [6, 4], max_new=6, sampling=sampling)
    oracle = _oracle(cfg, params, _clone(reqs))

    router = _router(cfg, params, n=2)
    for r in reqs:
        router.submit(r)
    for _ in range(kill_at):
        router.step()
    router.kill(0)
    router.run()
    assert {r.uid: list(r.output) for r in reqs} == oracle, (
        f"migration at step {kill_at} changed a sampled stream"
    )
    _assert_no_leaks(router)


def test_drain_finishes_active_lanes_in_place(dense_setup):
    """drain(): queued requests migrate immediately, active lanes finish on
    the draining replica (graceful), and undrain() reopens it."""
    cfg, params = dense_setup
    router = _router(cfg, params, n=2, max_batch=1)
    rng = np.random.default_rng(3)
    active = Request(uid=0, prompt=rng.integers(0, cfg.vocab, 5).tolist(),
                     max_new_tokens=6)
    queued = Request(uid=1, prompt=rng.integers(0, cfg.vocab, 4).tolist(),
                     max_new_tokens=6)
    router.submit(active)  # replica 0
    router.submit(queued)  # replica 1 (round robin)
    router.step()  # active takes replica 0's lane
    router.replicas[1].engine.queue.clear()  # re-stage: both on replica 0
    router.replicas[0].engine.queue.append(queued)
    router.drain(0)
    # The queued request moved to replica 1; the active lane stayed put.
    assert [r.uid for r in router.replicas[1].engine.queue] == [1]
    assert router.replicas[0].active() == 1
    assert router.replicas[0].state == DRAINING
    router.run()
    assert active.finish_reason == "length"
    assert queued.finish_reason == "length"
    assert router.replicas[0].engine.stats()["completed"] == 1
    # Pinned: the gate never healed it. undrain() does.
    assert router.replicas[0].state == DRAINING
    router.undrain(0)
    assert router.replicas[0].state == HEALTHY


def test_step_exception_kills_replica_not_router(dense_setup):
    cfg, params = dense_setup
    router = _router(cfg, params, n=2)
    reqs = _mk(np.random.default_rng(4), cfg.vocab, [5, 4], max_new=4)
    for r in reqs:
        router.submit(r)

    def boom():
        raise RuntimeError("device went away")

    router.replicas[0].engine.step = boom
    router.run()
    assert router.replicas[0].state == DEAD
    assert all(r.finish_reason == "length" for r in reqs)
    assert router.stats()["router_dead_replicas"] == 1.0


# ---------------------------------------------------------------------------
# Tentpole (c): health gate (faults, stragglers, heartbeat)


def test_fault_streak_opens_then_kills_breaker(dense_setup):
    """Quarantines on one replica walk it healthy -> draining -> dead
    through the fault-score breaker; bystanders complete oracle-exact on
    the survivor."""
    cfg, params = dense_setup
    rng = np.random.default_rng(5)
    reqs = _mk(rng, cfg.vocab, [5, 6, 4, 7], max_new=6)
    oracle = _oracle(cfg, params, _clone(reqs))
    router = _router(
        cfg, params, n=2,
        rconf=RouterConfig(placement="round_robin", degraded_after=1,
                           dead_after=2),
    )
    for r in reqs:
        router.submit(r)
    # Poison both requests routed to replica 0 (uids 0 and 2): the first
    # quarantine drains it, the second kills it.
    router.replicas[0].engine.inject_fault(0, 1)
    router.replicas[0].engine.inject_fault(2, 2)
    router.run()
    assert router.replicas[0].state == DEAD
    got = {r.uid: r.finish_reason for r in reqs}
    assert got[0] == "error" and got[2] == "error"
    for uid in (1, 3):
        r = next(x for x in reqs if x.uid == uid)
        assert r.finish_reason in ("eos", "length")
        assert list(r.output) == oracle[uid]
    s = router.stats()
    assert s["router_drained"] >= 1.0 and s["router_dead_replicas"] == 1.0
    _assert_no_leaks(router)


def test_straggler_drains_then_heals(dense_setup):
    """A stalled replica degrades via the router-side StepTimer and heals
    on the step that proves the stall passed — outputs unaffected."""
    cfg, params = dense_setup
    rng = np.random.default_rng(6)
    router = _router(
        cfg, params, n=2,
        rconf=RouterConfig(placement="round_robin", straggle_factor=3.0,
                           straggle_patience=2),
    )
    warm = _mk(rng, cfg.vocab, [5, 4], max_new=6)
    oracle = _oracle(cfg, params, _clone(warm))
    for r in warm:
        router.submit(r)
    router.run()  # warm jit + the step-time windows
    drained_before = router.stats()["router_drained"]

    reqs = _clone(warm)
    for r in reqs:
        router.submit(r)
    harness = ChaosHarness(
        router,
        FaultPlan((StallSteps(step=2, replica=0, steps=3, seconds=0.25),)),
    )
    harness.run()
    s = router.stats()
    assert s["router_drained"] - drained_before >= 1.0
    assert router.replicas[0].state == HEALTHY  # healed
    assert {r.uid: list(r.output) for r in reqs} == oracle
    _assert_no_leaks(router)


def test_fallback_strikes_decay_not_lifetime(dense_setup):
    """Kernel-fallback strikes are windowed, not cumulative: a replica that
    took fallbacks long ago scores clean again after fallback_forget_steps
    clean steps — lifetime totals must never walk a healthy replica to
    dead."""
    cfg, params = dense_setup
    router = _router(cfg, params, n=2,
                     rconf=RouterConfig(fallback_forget_steps=10))
    rep = router.replicas[0]
    rep.engine.kernel_fallbacks = 4  # lifetime total >= dead_after
    rep.engine.steps = 100
    assert rep.fault_score() == 4  # fresh strikes count in full
    rep.engine.steps = 120  # 20 clean steps -> 2 strikes forgiven
    assert rep.fault_score() == 2
    rep.engine.steps = 140  # all forgiven
    assert rep.fault_score() == 0
    # New fallbacks strike again from a clean slate.
    rep.engine.kernel_fallbacks = 5
    assert rep.fault_score() == 1
    # And the health gate no longer sees a dead replica either way.
    router._health_gate()
    assert rep.state != DEAD


def test_stale_heartbeat_kills_replica(dense_setup, tmp_path):
    """A replica whose heartbeat file stops advancing past the timeout is
    declared dead and its work migrates (the multi-process liveness path;
    the writer is silenced to simulate a wedged process)."""
    cfg, params = dense_setup
    hb = tmp_path / "hb.json"
    engines = [
        ServingEngine(cfg, params, EngineConfig(
            max_batch=2, max_len=64, page_size=8,
            heartbeat_path=str(hb) if i == 0 else None))
        for i in range(2)
    ]
    router = Router(ReplicaSet(engines),
                    RouterConfig(heartbeat_timeout_s=0.05, trace=True))
    reqs = _mk(np.random.default_rng(8), cfg.vocab, [4, 5], max_new=3)
    for r in reqs:
        router.submit(r)
    router.step()  # replica 0 beats once
    engines[0]._heartbeat.beat = lambda *a, **k: None  # writer wedges
    time.sleep(0.08)  # the last written beat ages past the timeout
    router.run()
    assert router.replicas[0].state == DEAD
    assert all(r.finish_reason == "length" for r in reqs)
    # The trail attributes the death to the heartbeat, not a fault streak.
    dead = [e for e in router.trace.events() if e.kind == "replica_dead"]
    assert [e.args["why"] for e in dead] == ["heartbeat_stale"]
    _assert_no_leaks(router)


# ---------------------------------------------------------------------------
# Tentpole (d): retry / timeout / backoff


def test_overloaded_carries_informed_retry_context(dense_setup):
    """Satellite 1: EngineOverloaded exposes queue_depth and a
    retry_after_hint derived from the step-time median x queue depth."""
    cfg, params = dense_setup
    eng = ServingEngine(cfg, params, EngineConfig(
        max_batch=1, max_len=64, max_queue=2))
    eng.submit(Request(uid=0, prompt=[1, 2, 3], max_new_tokens=8))
    eng.run()  # populate the step-time window
    eng.submit(Request(uid=1, prompt=[1, 2, 3], max_new_tokens=8))
    eng.submit(Request(uid=2, prompt=[4, 5, 6], max_new_tokens=8))
    with pytest.raises(EngineOverloaded) as ei:
        eng.submit(Request(uid=3, prompt=[7, 8, 9], max_new_tokens=8))
    assert ei.value.queue_depth == 2
    assert ei.value.retry_after_hint_s > 0.0
    assert ei.value.retry_after_hint_s == pytest.approx(
        eng._step_timer.percentile(50) * 2)


def test_router_retries_sheds_until_capacity_frees(dense_setup):
    """Bounded queues shed a burst; the router converts every shed into a
    backoff retry and all requests complete — router.submit never raises."""
    cfg, params = dense_setup
    router = _router(
        cfg, params, n=2, max_queue=1,
        rconf=RouterConfig(max_retries=10, backoff_base_s=0.01,
                           backoff_cap_s=0.1),
    )
    rng = np.random.default_rng(9)
    reqs = _mk(rng, cfg.vocab, [4, 5, 6, 4, 5, 6], max_new=4)
    oracle = _oracle(cfg, params, _clone(reqs))
    for r in reqs:
        router.submit(r)
    router.run(max_steps=100_000)
    s = router.stats()
    assert s["router_retried"] > 0
    assert s["router_shed"] == 0.0
    assert {r.uid: list(r.output) for r in reqs} == oracle
    _assert_no_leaks(router)


def test_stream_survives_transient_shed(dense_setup):
    """An engine-side shed the router retries must not leak its terminal
    'shed' marking into the stream: stream() stays open across the retry
    and yields the real tokens once capacity frees — no false sentinel."""
    cfg, params = dense_setup
    router = _router(
        cfg, params, n=1, max_queue=1, max_batch=1,
        rconf=RouterConfig(max_retries=20, backoff_base_s=0.001,
                           backoff_cap_s=0.01),
    )
    first = Request(uid=0, prompt=[1, 2, 3], max_new_tokens=4)
    burst = Request(uid=1, prompt=[4, 5, 6], max_new_tokens=4)
    router.submit(first)
    router.submit(burst)  # engine queue full -> shed -> router retry
    assert router.stats()["router_retried"] >= 1.0
    # The engine marked it terminal before raising; the router cleared the
    # marking because a retry is pending — the request is still live.
    assert burst.t_done == 0.0 and burst.finish_reason is None
    events = list(router.stream(burst))
    assert burst.finish_reason == "length"
    assert [e.token for e in events] == list(burst.output)
    assert events[-1].finished and events[-1].finish_reason == "length"
    assert all(e.token != -1 for e in events), "false shed sentinel"
    assert first.finish_reason == "length"
    _assert_no_leaks(router)


def test_retries_exhaust_to_terminal_shed(dense_setup):
    """With zero healthy replicas a request burns its retries and goes
    terminal 'shed' at the router; the stream yields one typed sentinel."""
    cfg, params = dense_setup
    router = _router(cfg, params, n=1,
                     rconf=RouterConfig(max_retries=2, backoff_base_s=0.001,
                                        backoff_cap_s=0.002))
    router.kill(0)
    req = Request(uid=0, prompt=[1, 2, 3], max_new_tokens=4)
    router.submit(req)
    events = list(router.stream(req))
    assert req.finish_reason == "shed" and req.t_done > 0.0
    assert req in router.done
    assert [e.finish_reason for e in events] == ["shed"]
    assert events[0].finished and events[0].token == -1
    assert router.stats()["router_shed"] == 1.0
    assert router.stats()["router_retried"] == 2.0


def test_end_to_end_deadline_survives_hops(dense_setup):
    """The deadline clock never resets across retry hops: a request whose
    remaining budget cannot absorb the backoff expires 'timeout' (not
    'shed', not a fresh per-engine deadline)."""
    cfg, params = dense_setup
    router = _router(
        cfg, params, n=1,
        rconf=RouterConfig(max_retries=50, backoff_base_s=0.05,
                           backoff_cap_s=0.05, backoff_jitter=0.0),
    )
    router.kill(0)
    req = Request(uid=0, prompt=[1, 2, 3], max_new_tokens=4, deadline_s=0.12)
    router.submit(req)
    t0 = time.perf_counter()
    router.run(max_steps=100_000)
    assert req.finish_reason == "timeout"
    assert router.stats()["router_timed_out"] == 1.0
    # Expired around the end-to-end budget, long before 50 retries' worth.
    assert time.perf_counter() - t0 < 1.0


def test_generate_streams_across_migration(dense_setup):
    """Satellite 2: the router's generate() facade streams TokenEvents with
    the terminal finish_reason even when the request migrates mid-stream."""
    cfg, params = dense_setup
    router = _router(cfg, params, n=2)
    events = []
    stream = router.generate([1, 2, 3, 4], max_new_tokens=5)
    for ev in stream:
        events.append(ev)
        if len(events) == 2:
            router.kill(router._placed[ev.uid])
    assert len(events) == 5
    assert events[-1].finished and events[-1].finish_reason == "length"
    assert [e.index for e in events] == list(range(5))
    _assert_no_leaks(router)


# ---------------------------------------------------------------------------
# Satellite: stats schema v9 + metrics exposition


def test_router_stats_schema_v9(dense_setup):
    cfg, params = dense_setup
    router = _router(cfg, params, n=2)
    req = Request(uid=0, prompt=[1, 2, 3], max_new_tokens=3)
    router.submit(req)
    router.run()
    s = router.stats()
    for key in (
        "router_steps", "router_placed", "router_retried", "router_migrated",
        "router_drained", "router_dead_replicas", "router_shed",
        "router_timed_out", "router_replicas", "router_healthy_replicas",
        "router_pending_retries", "router_migrate_p50_ms",
        "router_migrate_p95_ms",
    ):
        assert key in s, key
        assert isinstance(s[key], float), key
    for rid in range(2):
        assert s[f"replica{rid}_health"] == 1.0
        assert f"replica{rid}_step_p50_ms" in s
    assert s["router_placed"] == 1.0
    # Per-replica engine stats stay pure v8 — no router keys bleed in.
    eng_stats = router.replicas[0].engine.stats()
    assert not any(k.startswith("router_") for k in eng_stats)
    text = router.metrics_text()
    assert "router_placed" in text and "replica_health_0" in text
    assert "router_migrate_seconds_bucket" in text


# ---------------------------------------------------------------------------
# Satellite: chaos determinism


def test_chaos_plan_validation():
    with pytest.raises(TypeError):
        FaultPlan(("kill",))
    with pytest.raises(ValueError):
        FaultPlan((KillReplica(step=-1, replica=0),))
    plan = FaultPlan((KillReplica(step=3, replica=0),
                      InjectNaN(step=1, replica=1, uid=4)))
    assert plan.last_step == 3
    assert [f.step for f in plan.at(1)] == [1]


def test_chaos_same_plan_replays_bit_identical(dense_setup):
    """Two runs of one FaultPlan over cloned requests produce identical
    outputs, finish reasons, and router counters — chaos is scripted, not
    rolled."""
    cfg, params = dense_setup
    rng = np.random.default_rng(10)
    base = _mk(rng, cfg.vocab, [6, 5, 4, 7], max_new=6)
    plan = FaultPlan((InjectNaN(step=0, replica=1, uid=1),
                      DrainReplica(step=1, replica=2),
                      KillReplica(step=3, replica=0)))

    def run_once():
        router = _router(cfg, params, n=3)
        reqs = _clone(base)
        for r in reqs:
            router.submit(r)
        ChaosHarness(router, plan).run()
        _assert_no_leaks(router)
        s = router.stats()
        return (
            {r.uid: (r.finish_reason, list(r.output)) for r in reqs},
            (s["router_placed"], s["router_migrated"],
             s["router_dead_replicas"]),
        )

    out1, counters1 = run_once()
    out2, counters2 = run_once()
    assert out1 == out2
    assert counters1 == counters2
    assert counters1[2] == 1.0  # the scripted kill landed both times


def test_chaos_page_pressure_forces_preemption_under_router(dense_setup):
    """PagePressure starves a replica's pool mid-decode: the PR-6
    preemption path fires under the router, the harness releases its held
    pages at end of run, and everything completes with no leak."""
    cfg, params = dense_setup
    router = _router(cfg, params, n=1, n_pages=9, admission="optimistic")
    rng = np.random.default_rng(12)
    reqs = _mk(rng, cfg.vocab, [5, 5], max_new=14)
    for r in reqs:
        router.submit(r)
    harness = ChaosHarness(
        router,
        FaultPlan((PagePressure(step=2, replica=0, pages=3, hold_steps=30),)),
    )
    harness.run()
    eng = router.replicas[0].engine
    assert eng.stats()["preempted"] > 0, "held pages never starved the pool"
    assert all(r.finish_reason == "length" for r in reqs)
    assert not harness._held  # run() released everything it took
    assert eng.allocator.in_use() == 0
    _assert_no_leaks(router)


# ---------------------------------------------------------------------------
# Satellite: property test — router lifecycle never leaks pages


@settings(max_examples=10, deadline=None)
@given(st.lists(st.integers(min_value=0, max_value=6), min_size=1,
                max_size=20))
def test_property_router_lifecycle_never_leaks_pages(ops):
    """Random interleavings of submit / step / kill / drain / undrain /
    deadline-expiry hold ``in_use + available == capacity`` on EVERY
    replica after every event, and drain to zero pages on live replicas."""
    cfg, params = _setup("glm4-9b")
    router = Router(
        ReplicaSet.build(cfg, params,
                         EngineConfig(max_batch=2, max_len=64, page_size=8,
                                      max_queue=3), 2),
        RouterConfig(max_retries=2, backoff_base_s=0.001,
                     backoff_cap_s=0.005),
    )
    rng = np.random.default_rng(sum(ops) + len(ops))
    uid = 0
    live = []
    for op in ops:
        if op in (0, 1):  # submit short/long
            r = Request(
                uid=uid,
                prompt=rng.integers(0, cfg.vocab, 2 + op * 5).tolist(),
                max_new_tokens=3 + op * 10,
                deadline_s=None if op == 0 else 10.0,
            )
            uid += 1
            router.submit(r)  # never raises
            live.append(r)
        elif op == 2:  # kill a random replica (idempotent on dead)
            router.kill(int(rng.integers(0, 2)))
        elif op == 3:  # drain a random replica
            router.drain(int(rng.integers(0, 2)))
        elif op == 4:  # undrain (no-op unless draining)
            router.undrain(int(rng.integers(0, 2)))
        elif op == 5 and live:  # force a deadline expiry
            live[int(rng.integers(0, len(live)))].deadline_s = 0.0
        else:
            router.step()
        _assert_no_leaks(router)
        live = [r for r in live if r.t_done == 0.0]
    router.run(max_steps=50_000)
    _assert_no_leaks(router)
    for rep in router.replicas:
        if rep.state != DEAD:
            assert rep.engine.allocator.in_use() == 0
    # Bounded retries guarantee termination: every request left the router
    # with a terminal finish_reason (completed/error/shed/timeout).
    for r in live:
        assert r.t_done > 0.0, (r.uid, r.finish_reason)


# ---------------------------------------------------------------------------
# Mixed precision tiers: cross-tier migration is rejected, never resumed


def _mixed_router(cfg, params, tiers, rconf=None):
    """One replica per (kv_bits, matmul_mode) entry in ``tiers``."""
    engines = [
        ServingEngine(cfg, params, EngineConfig(
            **_ECONF, kv_bits=kv, matmul_mode=mm))
        for kv, mm in tiers
    ]
    return Router(ReplicaSet(engines),
                  rconf or RouterConfig(placement="round_robin"))


def test_replica_tier_identity(dense_setup):
    cfg, params = dense_setup
    router = _mixed_router(cfg, params,
                           [(8, "dequant"), (4, "dequant"), (None, "dequant")])
    assert router.replicas[0].tier == (8, "dequant")
    assert router.replicas[1].tier == (4, "dequant")
    assert router.replicas[2].tier == (0, "dequant")  # float pool


def test_cross_tier_migration_rejected_when_tier_extinct(dense_setup):
    """Kill the only int8 replica mid-decode in an {int8, int4} set: its
    in-flight request (committed tokens were produced over int8 KV) must
    NOT resume on the int4 survivor — it goes terminal 'tier_mismatch'.
    The survivor's own request is untouched."""
    cfg, params = dense_setup
    router = _mixed_router(cfg, params, [(8, "dequant"), (4, "dequant")])
    rng = np.random.default_rng(3)
    reqs = _mk(rng, cfg.vocab, [5, 6], max_new=8)
    for r in reqs:
        router.submit(r)  # round_robin: uid 0 -> rep 0 (kv8), uid 1 -> rep 1
    for _ in range(4):
        router.step()
    assert len(reqs[0].output) > 0  # committed tokens pin the tier
    router.kill(0)
    assert reqs[0].finish_reason == "tier_mismatch"
    assert reqs[0].t_done > 0.0
    s = router.stats()
    assert s["router_tier_rejected"] == 1.0
    assert s["router_migrated"] == 0.0
    router.run()
    assert reqs[1].finish_reason in ("eos", "length")  # survivor unaffected
    _assert_no_leaks(router)


def test_fresh_requests_cross_tiers_freely(dense_setup):
    """A harvested request with NO committed output carries no tier
    constraint — it restarts cleanly on any healthy replica."""
    cfg, params = dense_setup
    router = _mixed_router(cfg, params, [(8, "dequant"), (4, "dequant")])
    req = Request(uid=0, prompt=[1, 2, 3, 4], max_new_tokens=4)
    router.submit(req)  # round_robin -> rep 0 (kv8)
    router.kill(0)  # nothing committed yet: migrates to the int4 replica
    assert router.stats()["router_tier_rejected"] == 0.0
    assert router.stats()["router_migrated"] == 1.0
    router.run()
    assert req.finish_reason == "length"
    _assert_no_leaks(router)


def test_same_tier_migration_still_exact_in_mixed_set(dense_setup):
    """Two int8 replicas plus one int4: killing one int8 replica
    mid-decode resumes its lanes on the OTHER int8 replica (never the
    int4 one) and the output stays oracle-exact."""
    cfg, params = dense_setup
    router = _mixed_router(
        cfg, params, [(8, "dequant"), (8, "dequant"), (4, "dequant")])
    rng = np.random.default_rng(7)
    reqs = _mk(rng, cfg.vocab, [7, 5, 3], max_new=8)
    oracle = _oracle(cfg, params, _clone(reqs), kv_bits=8)
    for r in reqs:
        router.submit(r)  # uid i -> replica i (round_robin)
    for _ in range(4):
        router.step()
    assert len(reqs[0].output) > 0
    router.kill(0)
    assert router._placed.get(0) == 1, "must resume on the int8 peer"
    assert router.stats()["router_tier_rejected"] == 0.0
    router.run()
    # uids 0/1 decoded entirely over int8 KV -> oracle-exact; uid 2 lives
    # on the int4 replica (different numerics, no exactness claim).
    assert {r.uid: list(r.output) for r in reqs[:2]} == {
        u: oracle[u] for u in (0, 1)
    }
    assert reqs[2].finish_reason in ("eos", "length")
    _assert_no_leaks(router)


def test_stream_emits_tier_mismatch_sentinel(dense_setup):
    """A consumer streaming a request that gets tier-rejected mid-decode
    sees a finished=True event with finish_reason='tier_mismatch' instead
    of a silently-ending iterator."""
    cfg, params = dense_setup
    router = _mixed_router(cfg, params, [(8, "dequant"), (4, "dequant")])
    it = router.generate([1, 2, 3], max_new_tokens=16)  # -> rep 0 (kv8)
    events = [next(it)]  # at least one committed token pins the tier
    router.kill(0)
    events.extend(it)
    assert events[-1].finished
    assert events[-1].finish_reason == "tier_mismatch"
    _assert_no_leaks(router)
