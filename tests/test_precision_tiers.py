"""Sub-8-bit precision tier (ISSUE 10): int4 KV pages + W4A8 matmul.

The contracts under test:

* **nibble packing** — ``pack_int4``/``unpack_int4`` round-trip the full
  signed int4 range ``[-8, 7]`` for arbitrary even channel counts (the
  split-half byte layout: byte ``j`` holds channels ``j`` and ``j + C/2``);
  ``quant_rows`` at ``KV4_QMAX`` stays on the 15-level grid with the usual
  half-step reconstruction bound;
* **kv4 parity** — the three paged-attention paths (gather oracle, XLA
  online-softmax fallback, Pallas kernel in interpret mode) are *bitwise*
  identical on packed int4 pools — outputs AND appended pools — across page
  sizes and Q > 1 verify windows; trash-page poison changes nothing;
* **W4A8 matmul** — the Pallas kernel (interpret mode) is bit-exact against
  ``ref.w4a8_matmul_ref`` through the jitted ``ops.w4a8_matmul`` dispatch,
  including OCS-duplicated outlier channels and odd expanded contraction
  dims, and both match the float composition to int8-activation tolerance;
* **to_w4a8** — the outlier separator keeps exactly the ranked rows at
  8-bit, zeroes them inside ``w4`` (exact partition), pads odd expanded
  dims with a dead spec entry, preserves stacked (scan) layer dims, and
  separation strictly improves weight reconstruction on outlier-planted
  matrices (the acceptance criterion, weight-space edition);
* **config** — the precision-tier knobs reject invalid combinations at
  construction time (kv_bits vocabulary, int4-needs-paged, outlier-ratio
  range, w4a8 + incompatible spec drafter);
* **engine** — int4-KV serving agrees with int8-KV serving on a pinned
  knife-edge seed, W4A8 serving agrees with dequant serving (same bar as
  ``test_engine_w8a8_serving``), the combined sub-8-bit tier (int4 pages +
  W4A8 matmuls) serves to completion, and the v10 stats gauges report the
  tier.
"""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.configs import smoke_config
from repro.core import ocs
from repro.core.apply import quantize_params
from repro.core.recipe import QuantRecipe
from repro.kernels import ops
from repro.kernels import paged_attention as pa
from repro.models import transformer as T
from repro.serving import EngineConfig, Request, ServingEngine, SpecConfig
from repro.serving import kv_cache as kvc
from repro.serving.config import ConfigError


# ---------------------------------------------------------------------------
# Nibble packing


def test_pack_unpack_roundtrip_full_range():
    """Every signed int4 value survives the split-half byte layout."""
    q = jnp.asarray(np.arange(-8, 8, dtype=np.int8).reshape(1, 16))
    b = pa.pack_int4(q)
    assert b.dtype == jnp.uint8 and b.shape == (1, 8)
    np.testing.assert_array_equal(np.asarray(pa.unpack_int4(b)), np.asarray(q))


@settings(max_examples=25, deadline=None)
@given(
    rows=st.integers(1, 6),
    half=st.integers(1, 40),
    seed=st.integers(0, 10_000),
)
def test_pack_unpack_roundtrip_property(rows, half, seed):
    rng = np.random.RandomState(rows * 7919 + half * 131 + seed)
    q = rng.randint(-8, 8, (rows, 2 * half)).astype(np.int8)
    b = pa.pack_int4(jnp.asarray(q))
    assert b.dtype == jnp.uint8 and b.shape == (rows, half)
    np.testing.assert_array_equal(np.asarray(pa.unpack_int4(b)), q)


def test_pack_unpack_split_half_layout():
    """Byte j holds channel j in the low nibble, channel j + C/2 in the high."""
    q = jnp.asarray([[1, 2, 3, 4]], jnp.int8)
    b = np.asarray(pa.pack_int4(q))
    np.testing.assert_array_equal(b, [[(3 << 4) | 1, (4 << 4) | 2]])


def test_quant_rows_int4_grid():
    """qmax=KV4_QMAX stays on the 15-level grid; reconstruction within s/2."""
    rng = np.random.RandomState(4)
    x = jnp.asarray(rng.randn(6, 4, 32) * 3.0, jnp.float32)
    q, s = pa.quant_rows(x, qmax=pa.KV4_QMAX)
    qn = np.asarray(q, np.int32)
    assert qn.min() >= -7 and qn.max() <= 7
    err = np.abs(qn * np.asarray(s)[..., None] - np.asarray(x))
    assert (err <= np.asarray(s)[..., None] * 0.5 + 1e-6).all()


# ---------------------------------------------------------------------------
# kv4 three-way parity (gather oracle / XLA fallback / interpreted kernel)


def _mk_int4_pool(rng, P, KV, ps, hd):
    """Random packed int4 pages: arbitrary bytes unpack to nibbles in [-8, 7]."""
    return {
        "k": jnp.asarray(rng.randint(0, 256, (P, KV, ps, hd // 2)), jnp.uint8),
        "v": jnp.asarray(rng.randint(0, 256, (P, KV, ps, hd // 2)), jnp.uint8),
        "k_scale": jnp.asarray(rng.rand(P, KV, ps) * 0.1 + 0.01, jnp.float32),
        "v_scale": jnp.asarray(rng.rand(P, KV, ps) * 0.1 + 0.01, jnp.float32),
    }


def _mk_int4_case(rng, qn, ps, B=3, Tp=4, KV=2, rep=2, hd=16):
    """Ragged lanes: lane b owns b+2 pages (capped at Tp), the rest trash."""
    P = B * Tp + 1
    H = KV * rep
    pool = _mk_int4_pool(rng, P, KV, ps, hd)
    table = np.full((B, Tp), kvc.TRASH_PAGE, np.int32)
    pages = iter(range(1, P))
    pos = []
    for b in range(B):
        npg = min(Tp, b + 2)
        for t in range(npg):
            table[b, t] = next(pages)
        pos.append(max((npg - 1) * ps - qn - b, 0))
    return (
        pool,
        jnp.asarray(table),
        jnp.asarray(pos, jnp.int32),
        jnp.asarray(rng.randn(B, qn, H, hd), jnp.float32),
        jnp.asarray(rng.randn(B, qn, KV, hd), jnp.float32),
        jnp.asarray(rng.randn(B, qn, KV, hd), jnp.float32),
    )


_POOL_KEYS = ("k", "v", "k_scale", "v_scale")


@pytest.mark.parametrize("ps", [8, 16, 64])
@pytest.mark.parametrize("qn", [1, 4])
def test_int4_three_way_bitwise_parity(ps, qn):
    """int4 pages: all three paths share the dequant + online-softmax
    recurrence, so outputs AND appended pools are bitwise equal (the int8
    tier is only tolerance-equal here — its gather path requantizes)."""
    rng = np.random.RandomState(ps * 131 + qn)
    args = _mk_int4_case(rng, qn, ps)
    assert pa.pool_kind(args[0]) == "int4"
    o_g, p_g = ops.paged_attention(*args, force="gather")
    o_x, p_x = ops.paged_attention(*args, force="ref")
    o_k, p_k = ops.paged_attention(*args, force="interpret")
    np.testing.assert_array_equal(np.asarray(o_g), np.asarray(o_x))
    np.testing.assert_array_equal(np.asarray(o_g), np.asarray(o_k))
    for key in _POOL_KEYS:
        np.testing.assert_array_equal(np.asarray(p_g[key]), np.asarray(p_x[key]))
        np.testing.assert_array_equal(np.asarray(p_g[key]), np.asarray(p_k[key]))


@pytest.mark.parametrize("force", ["gather", "ref", "interpret"])
def test_int4_trash_page_invariant(force):
    """Poisoning page 0 (0xFF bytes, NaN scales) changes no lane's output."""
    rng = np.random.RandomState(99)
    args = _mk_int4_case(rng, 2, 16)
    clean, _ = ops.paged_attention(*args, force=force)
    pool = dict(args[0])
    pool["k"] = pool["k"].at[kvc.TRASH_PAGE].set(255)
    pool["v"] = pool["v"].at[kvc.TRASH_PAGE].set(255)
    pool["k_scale"] = pool["k_scale"].at[kvc.TRASH_PAGE].set(jnp.nan)
    pool["v_scale"] = pool["v_scale"].at[kvc.TRASH_PAGE].set(jnp.nan)
    dirty, _ = ops.paged_attention(pool, *args[1:], force=force)
    np.testing.assert_array_equal(np.asarray(clean), np.asarray(dirty))


def test_int4_pool_init_layout():
    """kv_bits=4 pools pack two channels per byte; scales keep int8 layout."""
    cfg = dataclasses.replace(smoke_config("deepseek-7b"), kv_bits=4)
    pool = kvc.init_page_pool(cfg, 5, 8)
    assert pool["k"].dtype == jnp.uint8
    assert pool["k"].shape == (5, cfg.n_kv_heads, 8, cfg.hd // 2)
    assert pool["k_scale"].shape == (5, cfg.n_kv_heads, 8)
    assert pa.pool_kind(pool) == "int4"
    # bytes/token halves the value payload vs int8; scales are unchanged.
    c4 = kvc.kv_bytes_per_token(cfg)
    c8 = kvc.kv_bytes_per_token(dataclasses.replace(cfg, kv_bits=8))
    per_row8 = 2 * cfg.hd + 2 * 4
    assert c8 - c4 == cfg.n_layers * cfg.n_kv_heads * (per_row8 - (cfg.hd + 8))


# ---------------------------------------------------------------------------
# W4A8 matmul: kernel vs ref bit-exactness through the jitted dispatch


def _mk_w4a8(rng, k, n, ratio, ocs_ratio):
    w = rng.randn(k, n).astype(np.float32)
    w[rng.choice(k, 3, replace=False)] *= 10.0  # plant outlier input channels
    lin = ocs.make_ocs_quant_linear(w, ocs_ratio, 8, per_channel=True, pad_to=1)
    lin4 = ocs.to_w4a8(lin, ratio)
    return w, lin4, lin4.spec.src[lin4.n_orig:]


@pytest.mark.parametrize(
    "k,n,ratio,ocs_ratio",
    [
        (128, 128, 0.0, 0.0),
        (128, 128, 0.1, 0.0),
        (96, 80, 0.0, 0.05),  # odd expanded dim: 96 + 5 -> padded to 102
        (96, 80, 0.1, 0.05),
        (200, 144, 0.25, 0.1),
    ],
)
def test_w4a8_kernel_bitexact_vs_ref(k, n, ratio, ocs_ratio):
    """force="ref" and force="interpret" agree bit for bit under jit (both
    share the reciprocal-multiply activation quant and the grouped
    ``acc*(a_s*s)`` epilogue)."""
    rng = np.random.RandomState(k * 7919 + n * 131 + int(ratio * 100) + int(ocs_ratio * 1000))
    _, lin4, src_tail = _mk_w4a8(rng, k, n, ratio, ocs_ratio)
    x = jnp.asarray(rng.randn(24, k), jnp.float32)
    a = (x, lin4.w4, lin4.s4, lin4.w8, lin4.s8, src_tail, lin4.outlier_idx)
    y_ref = ops.w4a8_matmul(*a, force="ref")
    y_krn = ops.w4a8_matmul(*a, force="interpret")
    np.testing.assert_array_equal(np.asarray(y_ref), np.asarray(y_krn))


def test_w4a8_matches_float_composition():
    """The two-accumulator partition equals q_exp @ dequant_weight."""
    rng = np.random.RandomState(17)
    _, lin4, src_tail = _mk_w4a8(rng, 128, 64, 0.1, 0.05)
    x = jnp.asarray(rng.randn(16, 128), jnp.float32)
    y = np.asarray(ops.w4a8_matmul(
        x, lin4.w4, lin4.s4, lin4.w8, lin4.s8, src_tail, lin4.outlier_idx,
        force="ref",
    ))
    q, a_s = pa.quant_rows(x, qmax=127.0)
    q_exp = jnp.concatenate([q, jnp.take(q, src_tail, axis=1)], axis=1)
    xf = np.asarray(q_exp, np.float32) * np.asarray(a_s)[:, None]
    want = xf @ np.asarray(lin4.dequant_weight())
    np.testing.assert_allclose(y, want, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# to_w4a8: outlier separation


def _plain_lin(rng, k=64, n=32, planted=()):
    w = rng.randn(k, n).astype(np.float32)
    for ch, mag in planted:
        w[ch] *= mag
    return w, ocs.make_ocs_quant_linear(w, 0.0, 8, per_channel=True, pad_to=1)


def test_to_w4a8_outlier_count_ranking_and_partition():
    rng = np.random.RandomState(3)
    w, lin = _plain_lin(rng, planted=[(5, 20.0), (17, 15.0)])
    lin4 = ocs.to_w4a8(lin, 0.1)
    assert lin4.n_outliers == ocs.n_splits_for_ratio(64, 0.1)
    assert lin4.k_expanded == 64
    oi = np.asarray(lin4.outlier_idx)
    assert {5, 17} <= set(oi.tolist())  # max|W| ranking catches the plants
    assert (np.diff(oi) > 0).all()  # sorted, unique
    # Outlier rows are zeroed inside w4: the accumulators partition the sum.
    wq = np.asarray(pa.unpack_int4(lin4.w4.T).T)
    assert (wq[oi] == 0).all()
    assert np.abs(wq).max() <= 7


def test_to_w4a8_separation_improves_reconstruction():
    """The acceptance criterion in weight space: separating the planted
    outlier channels shrinks the int4 grid for everything else."""
    rng = np.random.RandomState(8)
    w, lin = _plain_lin(rng, planted=[(2, 25.0), (9, 25.0), (33, 25.0)])
    def err(ratio):
        d = np.asarray(ocs.to_w4a8(lin, ratio).dequant_weight())
        return float(np.linalg.norm(d - w))
    assert err(0.1) < 0.5 * err(0.0)


def test_to_w4a8_odd_expanded_dim_pads_with_dead_spec_entry():
    rng = np.random.RandomState(12)
    w = rng.randn(63, 16).astype(np.float32)
    lin = ocs.make_ocs_quant_linear(w, 0.0, 8, per_channel=True, pad_to=1)
    lin4 = ocs.to_w4a8(lin, 0.0)
    assert lin4.k_expanded == 64
    assert lin4.spec.src.shape[-1] == 64
    assert float(lin4.spec.mult[-1]) == 0.0  # dead duplicate: contributes 0
    wq = np.asarray(pa.unpack_int4(lin4.w4.T).T)
    assert (wq[63] == 0).all()  # the pad row quantizes exactly to zero


def test_to_w4a8_stacked_leaves_keep_layer_dim():
    """Scan-sliced (stacked) leaves convert per layer with the lead dim kept."""
    from repro.core.apply import _quant_linear_stacked

    rng = np.random.RandomState(21)
    wa, _ = _plain_lin(rng, planted=[(4, 12.0)])
    wb, _ = _plain_lin(rng, planted=[(40, 12.0)])
    recipe = QuantRecipe(w_bits=8, ocs_ratio=0.0, per_channel=True, pad_to=1)
    stacked = _quant_linear_stacked(np.stack([wa, wb]), recipe)
    la = _quant_linear_stacked(wa, recipe)
    lb = _quant_linear_stacked(wb, recipe)
    l4 = ocs.to_w4a8(stacked, 0.1)
    assert l4.w4.shape == (2, 32, 32)
    assert l4.w8.shape[0] == 2
    pa_, pb_ = ocs.to_w4a8(la, 0.1), ocs.to_w4a8(lb, 0.1)
    np.testing.assert_array_equal(np.asarray(l4.w4[0]), np.asarray(pa_.w4))
    np.testing.assert_array_equal(np.asarray(l4.w4[1]), np.asarray(pb_.w4))
    np.testing.assert_array_equal(
        np.asarray(l4.outlier_idx[1]), np.asarray(pb_.outlier_idx)
    )


def test_to_w4a8_ratio_validation():
    rng = np.random.RandomState(1)
    _, lin = _plain_lin(rng)
    with pytest.raises(ValueError, match="ratio"):
        ocs.to_w4a8(lin, 1.5)


# ---------------------------------------------------------------------------
# Config validation


def test_engine_config_precision_validation():
    with pytest.raises(ValueError, match="kv_bits"):
        EngineConfig(kv_bits=5)
    with pytest.raises(ConfigError, match="int4"):
        EngineConfig(kv_bits=4, paged=False)
    with pytest.raises(ValueError, match="w4a8_outlier_ratio"):
        EngineConfig(w4a8_outlier_ratio=1.5)
    with pytest.raises(ConfigError, match="draft_mode"):
        EngineConfig(matmul_mode="w4a8", spec=SpecConfig())
    # The valid combinations construct fine.
    EngineConfig(kv_bits=4)
    EngineConfig(matmul_mode="w4a8", spec=SpecConfig(draft_mode="w4a8"))


# ---------------------------------------------------------------------------
# Engine integration


@pytest.fixture(scope="module")
def dense_setup():
    cfg = smoke_config("glm4-9b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


@pytest.fixture(scope="module")
def quant_setup(dense_setup):
    cfg, params = dense_setup
    recipe = QuantRecipe(w_bits=8, ocs_ratio=0.02, per_channel=True, pad_to=1)
    return cfg, quantize_params(params, recipe)


def _serve(cfg, params, seed, max_new=8, **conf):
    eng = ServingEngine(
        cfg, params, EngineConfig(max_batch=2, max_len=64, **conf)
    )
    rng = np.random.default_rng(seed)
    reqs = [
        Request(uid=i, prompt=rng.integers(0, cfg.vocab, n).tolist(),
                max_new_tokens=max_new)
        for i, n in enumerate([5, 11, 3, 17])
    ]
    for r in reqs:
        eng.submit(r)
    eng.run()
    assert all(r.finish_reason in ("eos", "length") for r in reqs)
    return eng, {r.uid: list(r.output) for r in reqs}


def _agreement(a, b):
    tot = match = 0
    for uid in a:
        for x, y in zip(a[uid], b[uid]):
            tot += 1
            match += int(x == y)
    return match, tot


def test_engine_int4_vs_int8_token_agreement(dense_setup):
    """Pinned knife-edge seed: the random-weight smoke model flips argmax
    easily under 4-bit KV error, so assert majority agreement, not identity
    (seed 7 observed 22/32)."""
    cfg, params = dense_setup
    eng8, o8 = _serve(cfg, params, 7, kv_bits=8)
    eng4, o4 = _serve(cfg, params, 7, kv_bits=4)
    match, tot = _agreement(o8, o4)
    assert tot == 32 and match >= 16, (match, tot)
    s8, s4 = eng8.stats(), eng4.stats()
    assert s8["kv_bits"] == 8.0 and s4["kv_bits"] == 4.0
    assert 0 < s4["kv_bytes_per_token"] < s8["kv_bytes_per_token"]
    assert s4["kv_pool_capacity_tokens"] > 0


def test_engine_w4a8_serving_agreement(quant_setup):
    """W4A8 must stay close to dequant serving on the same quantized tree —
    the same bar as test_engine_w8a8_serving, one tier down (seed 2
    observed 15/32 on the random-weight smoke model)."""
    cfg, qparams = quant_setup
    _, od = _serve(cfg, qparams, 2)
    engw, ow = _serve(
        cfg, qparams, 2, matmul_mode="w4a8", w4a8_outlier_ratio=0.25
    )
    match, tot = _agreement(od, ow)
    assert tot == 32 and match >= 8, (match, tot)
    assert engw.stats()["completed"] == 4


def test_engine_combined_sub8_tier_serves(quant_setup):
    """The full sub-8-bit tier — int4 KV pages AND W4A8 matmuls — serves
    every request to completion with the v10 gauges reporting the tier."""
    cfg, qparams = quant_setup
    eng, out = _serve(
        cfg, qparams, 5,
        kv_bits=4, matmul_mode="w4a8", w4a8_outlier_ratio=0.25,
    )
    assert all(len(v) == 8 for v in out.values())
    s = eng.stats()
    assert s["kv_bits"] == 4.0 and s["completed"] == 4
