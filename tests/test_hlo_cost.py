"""Unit tests for the trip-count-aware HLO cost model (roofline §Methodology)."""
import textwrap

from repro.launch.hlo_cost import analyze_hlo

# Minimal synthetic HLO: a while loop with known trip count 8 whose body does
# one f32[64,64]x[64,64] dot, one all-reduce of f32[64,64], and one
# dynamic-update-slice into an f32[8,64,64] stacked buffer.
HLO = textwrap.dedent("""
    HloModule test

    %body (p: (s32[], f32[64,64], f32[8,64,64])) -> (s32[], f32[64,64], f32[8,64,64]) {
      %p = (s32[], f32[64,64], f32[8,64,64]) parameter(0)
      %i = s32[] get-tuple-element(%p), index=0
      %x = f32[64,64]{1,0} get-tuple-element(%p), index=1
      %buf = f32[8,64,64]{2,1,0} get-tuple-element(%p), index=2
      %d = f32[64,64]{1,0} dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      %ar = f32[64,64]{1,0} all-reduce(%d), replica_groups={}, to_apply=%sum
      %xr = f32[1,64,64]{2,1,0} reshape(%ar)
      %zero = s32[] constant(0)
      %dus = f32[8,64,64]{2,1,0} dynamic-update-slice(%buf, %xr, %i, %zero, %zero)
      %one = s32[] constant(1)
      %ip = s32[] add(%i, %one)
      ROOT %t = (s32[], f32[64,64], f32[8,64,64]) tuple(%ip, %ar, %dus)
    }

    %cond (pc: (s32[], f32[64,64], f32[8,64,64])) -> pred[] {
      %pc = (s32[], f32[64,64], f32[8,64,64]) parameter(0)
      %ic = s32[] get-tuple-element(%pc), index=0
      %n = s32[] constant(8)
      ROOT %lt = pred[] compare(%ic, %n), direction=LT
    }

    %sum (a: f32[], b: f32[]) -> f32[] {
      %a = f32[] parameter(0)
      %b = f32[] parameter(1)
      ROOT %s = f32[] add(%a, %b)
    }

    ENTRY %main (in: f32[64,64]) -> (s32[], f32[64,64], f32[8,64,64]) {
      %in = f32[64,64]{1,0} parameter(0)
      %c0 = s32[] constant(0)
      %b0 = f32[8,64,64]{2,1,0} broadcast(%c0), dimensions={}
      %init = (s32[], f32[64,64], f32[8,64,64]) tuple(%c0, %in, %b0)
      ROOT %w = (s32[], f32[64,64], f32[8,64,64]) while(%init), condition=%cond, body=%body
    }
""")


def test_trip_count_scaling():
    c = analyze_hlo(HLO)
    # dot flops: 2 * 64*64 * 64 per trip, x8 trips.
    assert c.flops >= 2 * 64 * 64 * 64 * 8
    # elementwise add contributes a little; dots dominate.
    assert c.flops < 2 * 64 * 64 * 64 * 8 * 1.2


def test_collectives_scaled_by_trips():
    c = analyze_hlo(HLO)
    # all-reduce result bytes: 64*64*4 per trip, x8.
    assert c.collective_bytes["all-reduce"] == 64 * 64 * 4 * 8
    assert c.collective_counts["all-reduce"] == 8


def test_stacked_buffer_not_overcounted():
    c = analyze_hlo(HLO)
    buf = 8 * 64 * 64 * 4
    # The [8,64,64] DUS must be charged ~once over the loop (result/T per
    # trip), NOT 8 full buffers: total stacked-kind bytes stay ~2x buffer
    # (operand+result regions), far below 8x.
    dus = c.bytes_by_kind.get("dynamic-update-slice", 0.0)
    assert dus <= 2.5 * buf, (dus, buf)
    assert dus >= 0.5 * buf
