"""Paged KV-cache subsystem (ISSUE 2): page pools, block tables, prefix reuse.

The acceptance bar: paged decode is *bit-exact* against the contiguous f32
cache in float-page mode (the gather reconstructs the dense layout), page
admission control recycles pages so workloads larger than the pool complete
(impossible with fixed-slot caches), and refcounted prefix sharing serves a
repeated system prompt without re-prefilling it — with identical outputs.
"""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import smoke_config
from repro.models import transformer as T
from repro.serving import (EngineConfig, PageAllocator, Request,
                           ServingEngine, pages_needed)
from repro.serving import kv_cache as kvc


def _mk_requests(rng, vocab, lengths, max_new=5, eos=None):
    return [
        Request(
            uid=i,
            prompt=rng.integers(0, vocab, n).tolist(),
            max_new_tokens=max_new,
            eos_id=eos,
        )
        for i, n in enumerate(lengths)
    ]


@pytest.fixture(scope="module")
def dense_setup():
    cfg = smoke_config("glm4-9b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


# ---------------------------------------------------------------------------
# Allocator (host-side, no jax)


def test_pages_needed():
    assert pages_needed(0, 16) == 0
    assert pages_needed(1, 16) == 1
    assert pages_needed(16, 16) == 1
    assert pages_needed(17, 16) == 2


def test_allocator_alloc_free_refcount():
    a = PageAllocator(n_pages=5, page_size=4)  # capacity 4 (page 0 is trash)
    assert a.capacity == 4 and a.available() == 4
    ids = a.alloc(3)
    assert len(ids) == 3 and 0 not in ids and a.in_use() == 3
    a.retain(ids[0])
    a.release(ids)  # ids[0] still referenced once
    assert a.in_use() == 1 and a.available() == 3
    a.release([ids[0]])
    assert a.in_use() == 0 and a.available() == 4
    with pytest.raises(RuntimeError):
        a.alloc(5)
    assert a.peak_in_use == 3


def test_allocator_prefix_match_register_evict():
    a = PageAllocator(n_pages=4, page_size=2)  # capacity 3
    toks = [1, 2, 3, 4, 5]
    hits, keys = a.match_prefix(toks, max_pages=2)
    assert hits == [] and len(keys) == 2  # 2 full pages of 5 tokens
    ids = a.alloc(2)
    a.register(keys[0], ids[0])
    a.register(keys[1], ids[1])
    a.release(ids)  # zero-ref but cached: still hit-able, still allocatable
    assert a.in_use() == 0 and a.cached_pages() == 2 and a.available() == 3

    hits2, _ = a.match_prefix(toks, max_pages=2)
    assert hits2 == ids and a.in_use() == 2  # revived from the LRU
    # chained hash: a different second block must not hit past page 0
    hits3, _ = a.match_prefix([1, 2, 9, 9], max_pages=2)
    assert hits3 == [ids[0]]
    a.release(hits2)
    a.release(hits3)

    # pool pressure evicts cached pages (oldest first) back into circulation
    got = a.alloc(3)
    assert sorted(got) == sorted([ids[0], ids[1]] + [a for a in got if a not in ids])
    assert a.cached_pages() == 0
    assert a.match_prefix(toks, max_pages=2)[0] == []  # cache gone after evict


# ---------------------------------------------------------------------------
# Paged decode correctness (model layer)


@pytest.mark.parametrize("kv_bits", [None, 8])
def test_paged_decode_bitexact_vs_contiguous(kv_bits):
    """The layout invariant: gathering pool[table] reconstructs the dense
    cache, so paged decode logits equal contiguous-cache logits *bitwise* —
    float pages and int8 pages alike (same quant grid, same values)."""
    cfg = dataclasses.replace(smoke_config("deepseek-7b"), kv_bits=kv_bits)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)
    tokens = rng.randint(0, cfg.vocab, (2, 12))
    B, L, ps = 2, 32, 8

    def decode_all(paged):
        if paged:
            t = L // ps
            caches = kvc.init_paged_cache(cfg, B, B * t + 1, ps, t, dtype=jnp.float32)
            table = np.arange(1, B * t + 1, dtype=np.int32).reshape(B, t)
            caches["table"] = jnp.asarray(table)
        else:
            caches = T.init_cache(cfg, B, L, dtype=jnp.float32)
        outs = []
        for i in range(tokens.shape[1]):
            lg, caches = T.decode_step(
                params, jnp.asarray(tokens[:, i : i + 1]), caches, cfg
            )
            outs.append(np.asarray(lg, np.float32))
        return np.stack(outs)

    np.testing.assert_array_equal(decode_all(False), decode_all(True))


def test_paged_engine_matches_unpaged(dense_setup):
    """End-to-end float-page parity: the paged engine emits exactly the
    tokens of the fixed-slot engine for a mixed-length workload."""
    cfg, params = dense_setup
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, cfg.vocab, n).tolist() for n in [3, 11, 6, 21]]

    def run(paged):
        eng = ServingEngine(cfg, params, EngineConfig(max_batch=3, max_len=64, paged=paged))
        for i, p in enumerate(prompts):
            eng.submit(Request(uid=i, prompt=list(p), max_new_tokens=6))
        return {r.uid: r.output for r in eng.run()}

    assert run(True) == run(False)


def test_paged_engine_matches_unpaged_moe():
    """MoE blocks serve through the paged cache too (attention is the only
    cached state; expert routing is stateless)."""
    cfg = smoke_config("deepseek-moe-16b")
    params = T.init_params(cfg, jax.random.PRNGKey(1))
    rng = np.random.default_rng(2)
    prompts = [rng.integers(0, cfg.vocab, n).tolist() for n in [4, 13]]

    def run(paged):
        eng = ServingEngine(cfg, params, EngineConfig(max_batch=2, max_len=64, paged=paged))
        for i, p in enumerate(prompts):
            eng.submit(Request(uid=i, prompt=list(p), max_new_tokens=4))
        return {r.uid: r.output for r in eng.run()}

    assert run(True) == run(False)


# ---------------------------------------------------------------------------
# Engine: reclamation, recycling, backpressure


def test_page_reclamation_across_retire_admit_cycles(dense_setup):
    """Pages free on retirement and get reused by later admissions: a
    workload whose total footprint is several times the pool completes, and
    the pool drains back to zero referenced pages."""
    cfg, params = dense_setup
    rng = np.random.default_rng(11)
    # capacity 8 pages = 128 cache tokens, far below max_batch * max_len
    eng = ServingEngine(cfg, params, EngineConfig(max_batch=4, max_len=64, n_pages=9))
    lengths = [int(rng.integers(4, 30)) for _ in range(8)]
    reqs = _mk_requests(rng, cfg.vocab, lengths, max_new=6)
    for r in reqs:
        eng.submit(r)
    done = eng.run()
    s = eng.stats()
    assert len(done) == 8 and all(len(r.output) == 6 for r in done)
    total = sum(n + 6 for n in lengths)
    assert total > s["kv_pages_capacity"] * s["kv_page_size"]  # oversubscribed
    assert s["kv_pages_peak"] <= s["kv_pages_capacity"]
    assert s["kv_pages_in_use"] == 0  # everything reclaimed
    # the drained engine is immediately reusable
    eng.submit(Request(uid=99, prompt=[1, 2, 3], max_new_tokens=3))
    assert len(eng.run()) == 9


def test_page_exhaustion_backpressure_queues(dense_setup):
    """When the pool can't hold another request, admission *waits* (FIFO)
    instead of crashing; a request larger than the whole pool is rejected at
    submit so it can never deadlock the queue."""
    cfg, params = dense_setup
    rng = np.random.default_rng(13)
    eng = ServingEngine(cfg, params, EngineConfig(max_batch=4, max_len=64, n_pages=4))
    # each request needs 2 pages (17 + 5 tokens @ ps=16); pool holds 1 at once
    reqs = _mk_requests(rng, cfg.vocab, [17, 17, 17, 17], max_new=5)
    for r in reqs:
        eng.submit(r)
    done = eng.run()
    s = eng.stats()
    assert len(done) == 4
    assert s["kv_pages_peak"] <= s["kv_pages_capacity"] == 3
    with pytest.raises(ValueError):  # needs 4 pages; capacity is 3
        eng.submit(Request(uid=9, prompt=list(range(60)), max_new_tokens=4))


# ---------------------------------------------------------------------------
# Prefix sharing


def test_shared_prefix_batched_matches_solo(dense_setup):
    """Refcounted prefix sharing: requests sharing a system prompt decode
    batched off shared pages exactly as they decode solo from a cold engine
    (float-page mode — bit-exact pages, greedy argmax, identical tokens)."""
    cfg, params = dense_setup
    rng = np.random.default_rng(3)
    sys_prompt = rng.integers(0, cfg.vocab, 33).tolist()  # 2 full pages @ 16
    tails = [rng.integers(0, cfg.vocab, k).tolist() for k in (5, 9, 2)]

    solo = []
    for t in tails:
        eng = ServingEngine(cfg, params, EngineConfig(max_batch=1, max_len=64))
        eng.submit(Request(uid=0, prompt=sys_prompt + t, max_new_tokens=5))
        solo.append(eng.run()[0].output)

    eng = ServingEngine(cfg, params, EngineConfig(max_batch=3, max_len=64))
    for i, t in enumerate(tails):
        eng.submit(Request(uid=i, prompt=sys_prompt + t, max_new_tokens=5))
    done = {r.uid: r.output for r in eng.run()}
    for i in range(len(tails)):
        assert done[i] == solo[i], f"uid={i}"
    s = eng.stats()
    # requests 2 and 3 each hit the 2 full prefix pages written by request 1
    assert s["prefix_hit_pages"] == 4 and s["prefix_hit_rate"] > 0


def test_repeated_prompt_prefills_once(dense_setup):
    """A repeated system prompt's shared pages prefill once: a repeat
    prefills only the suffix past its prefix hit. Hits are capped at
    (n-1)//page_size pages so the prefill keeps >= 1 real token: a 33-token
    prompt hits both full pages and reruns a 1-token suffix."""
    cfg, params = dense_setup
    rng = np.random.default_rng(9)
    prompt = rng.integers(0, cfg.vocab, 33).tolist()  # 2 full pages + 1 tail
    eng = ServingEngine(cfg, params, EngineConfig(max_batch=1, max_len=64))
    for i in range(3):
        eng.submit(Request(uid=i, prompt=list(prompt), max_new_tokens=4))
    done = {r.uid: r.output for r in eng.run()}
    assert done[0] == done[1] == done[2]
    s = eng.stats()
    # cold: 33 tokens; repeats: 1-token suffix each
    assert s["prefill_tokens"] == 33 + 1 + 1, s["prefill_tokens"]
    assert s["prefix_hit_pages"] == 4  # two full pages per repeat
    # cached pages survive retirement and still drain from in_use
    assert s["kv_pages_in_use"] == 0 and s["kv_pages_cached"] > 0


def test_prefix_pages_shared_not_copied(dense_setup):
    """Refcounting, not copying: two live sequences with the same prompt
    hold strictly fewer pages than two independent allocations."""
    cfg, params = dense_setup
    rng = np.random.default_rng(21)
    prompt = rng.integers(0, cfg.vocab, 32).tolist()
    eng = ServingEngine(cfg, params, EngineConfig(max_batch=2, max_len=64))
    # long decode budgets keep both sequences live simultaneously
    for i in range(2):
        eng.submit(Request(uid=i, prompt=list(prompt), max_new_tokens=8))
    eng.step()  # admits both (same _admit pass), decodes one token
    s = eng.stats()
    independent = 2 * pages_needed(32 + 8, 16)
    assert s["kv_pages_in_use"] < independent
    assert s["kv_pages_in_use"] == pages_needed(32 + 8, 16) + 2  # shared + own
    eng.run()


# ---------------------------------------------------------------------------
# Satellites: eos on the prefill token


@pytest.mark.parametrize("paged", [True, False])
def test_eos_on_first_token_retires_immediately(dense_setup, paged):
    """An immediate-eos request must not burn max_new_tokens-1 decode steps
    (or hold pages/a lane): probe the greedy first token, then resubmit with
    it as eos_id."""
    cfg, params = dense_setup
    rng = np.random.default_rng(17)
    prompt = rng.integers(0, cfg.vocab, 9).tolist()
    eng = ServingEngine(cfg, params, EngineConfig(max_batch=1, max_len=64, paged=paged))
    eng.submit(Request(uid=0, prompt=list(prompt), max_new_tokens=8))
    first = eng.run()[0].output[0]

    eng2 = ServingEngine(cfg, params, EngineConfig(max_batch=1, max_len=64, paged=paged))
    eng2.submit(Request(uid=0, prompt=list(prompt), max_new_tokens=8, eos_id=first))
    done = eng2.run()
    s = eng2.stats()
    assert len(done) == 1 and done[0].output == [first]
    assert done[0].t_done > 0
    assert s["decode_steps"] == 0  # zero decode work
    assert s["kv_pages_in_use"] == 0  # pages reclaimed at once (paged mode)


def test_max_new_tokens_one(dense_setup):
    cfg, params = dense_setup
    eng = ServingEngine(cfg, params, EngineConfig(max_batch=1, max_len=64))
    eng.submit(Request(uid=0, prompt=[5, 6, 7], max_new_tokens=1))
    done = eng.run()
    assert len(done) == 1 and len(done[0].output) == 1
    assert eng.stats()["decode_steps"] == 0
