"""Convnet + LSTM benchmark subjects and the activation-quant context."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import tap
from repro.core.actquant import ActQuantCtx, act_quant_ctx, post_ocs_clip
from repro.core.ocs import split_activations_spec
from repro.models.convnet import (
    ConvNetConfig,
    conv_w_from_2d,
    conv_w_to_2d,
    convnet_forward,
    convnet_loss,
    init_convnet,
    make_synthetic_images,
)
from repro.models.lstm import (
    LSTMConfig,
    init_lstm,
    lstm_forward,
    lstm_loss,
)

CFG = ConvNetConfig(n_classes=4, width=8, n_blocks=1, img=8)


def test_convnet_shapes_and_grad():
    params = init_convnet(CFG, jax.random.PRNGKey(0))
    d = make_synthetic_images(4, CFG, seed=0)
    logits = convnet_forward(params, jnp.asarray(d["images"]), CFG)
    assert logits.shape == (4, CFG.n_classes)
    assert not np.any(np.isnan(np.asarray(logits)))
    loss, grads = jax.value_and_grad(convnet_loss)(
        params, {"images": jnp.asarray(d["images"]),
                 "labels": jnp.asarray(d["labels"])}, CFG)
    assert np.isfinite(float(loss))
    gnorm = sum(float(jnp.sum(g * g)) for g in jax.tree.leaves(grads))
    assert gnorm > 0


def test_conv_matricization_roundtrip():
    rng = np.random.RandomState(0)
    w = rng.randn(3, 3, 8, 16).astype(np.float32)
    w2d = conv_w_to_2d(w)
    assert w2d.shape == (8, 3 * 3 * 16)
    np.testing.assert_array_equal(conv_w_from_2d(w2d, (3, 3), 16), w)


def test_conv_ocs_channel_split_equivalence():
    """Matricized row split == duplicating the 2D activation channel (Eq. 3)."""
    from repro.core.ocs import split_weights

    rng = np.random.RandomState(1)
    w = rng.randn(3, 3, 6, 5).astype(np.float32)
    w[:, :, 2, :] *= 10.0  # make channel 2 the outlier
    x = jnp.asarray(rng.randn(2, 8, 8, 6), jnp.float32)

    w2d = conv_w_to_2d(w)
    # ceil(0.17 * 6) = 2 splits; both target outlier channel 2 (its halves
    # remain the largest values after the first split).
    w2d_exp, spec, _ = split_weights(w2d, ratio=0.17, bits=8, qa=False)
    assert spec.n_expanded == 8
    assert int(spec.src[-1]) == 2 and int(spec.src[-2]) == 2
    w_exp = conv_w_from_2d(w2d_exp, (3, 3), 5)

    x_exp = jnp.take(x, jnp.asarray(np.asarray(spec.src)), axis=-1)
    conv = lambda xx, ww: jax.lax.conv_general_dilated(
        xx, ww, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"))
    np.testing.assert_allclose(
        conv(x_exp, jnp.asarray(w_exp)), conv(x, jnp.asarray(w)),
        rtol=1e-4, atol=1e-4)


def test_lstm_forward_and_learning_signal():
    cfg = LSTMConfig(vocab=32, hidden=16, n_layers=2)
    params = init_lstm(cfg, jax.random.PRNGKey(0))
    tokens = jnp.asarray(np.random.RandomState(0).randint(0, 32, (2, 12)))
    logits = lstm_forward(params, tokens, cfg)
    assert logits.shape == (2, 12, 32)
    assert not np.any(np.isnan(np.asarray(logits)))
    batch = {"tokens": tokens, "labels": jnp.roll(tokens, -1, axis=1)}
    loss, grads = jax.value_and_grad(lstm_loss)(params, batch, cfg)
    assert np.isfinite(float(loss))
    assert float(jnp.abs(grads["l0"]["wx"]).max()) > 0


def test_tap_collector_per_layer_sites():
    """Ordinals separate repeated site names across layers."""
    params = init_convnet(CFG, jax.random.PRNGKey(0))
    coll = tap.Collector()
    d = make_synthetic_images(2, CFG, seed=0)
    with tap.collecting(coll):
        for _ in range(2):
            coll.begin_batch()
            convnet_forward(params, jnp.asarray(d["images"]), CFG)
    # n_blocks=1, 3 stages -> 6 conv sites + fc; all distinct keys.
    assert len(coll) == 7, sorted(coll.sites)
    assert "s0b0_c1#0" in coll.sites and "fc#0" in coll.sites
    assert coll.sites["fc#0"].hist.total > 0


def test_act_quant_ctx_expands_and_quantizes():
    params = init_convnet(CFG, jax.random.PRNGKey(0))
    coll = tap.Collector()
    d = make_synthetic_images(4, CFG, seed=0)
    x = jnp.asarray(d["images"])
    with tap.collecting(coll):
        coll.begin_batch()
        base = convnet_forward(params, x, CFG)

    clips, specs = {}, {}
    for site, stats in coll.sites.items():
        spec = split_activations_spec(stats, 0.05)
        specs[site] = spec
        clips[site] = post_ocs_clip(stats, spec, None, 8)
    ctx = ActQuantCtx(bits=8, clips=clips, specs=specs)

    def fwd(p, xx):
        ctx.reset()
        return convnet_forward(p, xx, CFG)

    with act_quant_ctx(ctx):
        out = jax.jit(fwd)(params, x)
    # 8-bit with OCS: functionally close to float (quant error only).
    np.testing.assert_allclose(np.asarray(out), np.asarray(base),
                               rtol=0.1, atol=0.35)
    # And genuinely quantized: some difference must exist.
    assert float(jnp.abs(out - base).max()) > 0


def test_act_quant_oracle_path():
    from repro.models.layers import dense

    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(4, 16), jnp.float32)
    w = jnp.asarray(rng.randn(16, 8), jnp.float32)
    base = x @ w
    ctx = ActQuantCtx(bits=8, clips={"lin#0": float(jnp.abs(x).max())},
                      oracle_ratio=0.1)
    with act_quant_ctx(ctx):
        ctx.reset()
        out = dense(w, x, name="lin")
    np.testing.assert_allclose(np.asarray(out), np.asarray(base),
                               rtol=0.05, atol=0.05)
