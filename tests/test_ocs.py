"""Tests for Outlier Channel Splitting — the paper's core contribution.

Key invariants (each maps to a claim in the paper):
* Hermite identity: Q(w) == Q((w-Δ/2)/2) + Q((w+Δ/2)/2)        (§3.3, Eq. 7)
* Functional equivalence of the expanded float network            (§3.2)
* Channel selection targets the global max |value|                (§3.4)
* ceil(r*C) splits / overhead ≈ r                                 (§3.4, Table 5)
* QA splitting quantization error <= naive splitting error        (§3.3, Table 1)
* Oracle OCS halves the batch's own outlier channels              (Table 4)
"""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (
    ChannelStats,
    OCSSpec,
    collapse_expanded,
    duplicate_weight_rows,
    expand_activations,
    fake_quant,
    make_ocs_quant_linear,
    n_splits_for_ratio,
    oracle_expand,
    qmax,
    split_activations_spec,
    split_weights,
)


def _Q(v, delta):
    """Paper §3.3 rounding: Q(v) = Δ * floor(v/Δ + 1/2)."""
    return delta * np.floor(v / delta + 0.5)


@settings(max_examples=300, deadline=None)
@given(
    st.floats(min_value=-1000, max_value=1000, allow_nan=False),
    st.floats(min_value=1e-3, max_value=10, allow_nan=False),
)
def test_hermite_identity(w, delta):
    """Q(w) == Q((w-Δ/2)/2) + Q((w+Δ/2)/2) exactly (Eq. 7)."""
    lhs = _Q(w, delta)
    rhs = _Q((w - delta / 2) / 2, delta) + _Q((w + delta / 2) / 2, delta)
    assert lhs == pytest.approx(rhs, abs=1e-3 * delta)


@settings(max_examples=100, deadline=None)
@given(
    st.floats(min_value=-100, max_value=100, allow_nan=False),
    st.floats(min_value=1e-2, max_value=5, allow_nan=False),
)
def test_naive_split_error_at_midpoints(w, delta):
    """Naive split can double max error; QA split never exceeds it (§3.3)."""
    qa_err = abs(_Q(w, delta) - (_Q((w - delta / 2) / 2, delta) + _Q((w + delta / 2) / 2, delta)))
    assert qa_err <= 1e-3 * delta  # QA is exact


def test_naive_split_error_example():
    """Paper's example: w=3, halves 1.5/1.5 both round up -> 4 != 3 (Δ=1)."""
    w, delta = 3.0, 1.0
    naive = _Q(w / 2, delta) + _Q(w / 2, delta)
    assert naive == 4.0 and _Q(w, delta) == 3.0


# ---------------------------------------------------------------------------
# Functional equivalence


def test_weight_ocs_functional_equivalence():
    rng = np.random.default_rng(0)
    w = rng.normal(size=(32, 16)).astype(np.float32)
    w[5, 3] = 10.0  # planted outlier
    w_exp, spec, _ = split_weights(w, 0.1, 8, qa=True)
    x = rng.normal(size=(4, 32)).astype(np.float32)
    y_ref = x @ w
    y_exp = np.asarray(expand_activations(jnp.asarray(x), spec)) @ w_exp
    np.testing.assert_allclose(y_exp, y_ref, rtol=1e-4, atol=1e-5)


def test_weight_ocs_collapse_identity():
    rng = np.random.default_rng(1)
    w = rng.normal(size=(16, 8)).astype(np.float32)
    w_exp, spec, _ = split_weights(w, 0.2, 6, qa=True)
    w_eff, y_bias = collapse_expanded(w_exp, spec, 16)
    np.testing.assert_allclose(w_eff, w, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(y_bias, 0.0, atol=1e-5)


def test_activation_ocs_functional_equivalence():
    rng = np.random.default_rng(2)
    c = 24
    w = rng.normal(size=(c, 8)).astype(np.float32)
    stats = ChannelStats(n_channels=c)
    x_cal = rng.normal(size=(64, c)).astype(np.float32)
    x_cal[:, 7] *= 5.0  # channel 7 has outliers
    stats.update(x_cal)
    spec = split_activations_spec(stats, 0.05)
    assert 7 in np.asarray(spec.src[c:])  # the outlier channel got split
    w_exp = np.asarray(duplicate_weight_rows(jnp.asarray(w), spec))
    x = rng.normal(size=(4, c)).astype(np.float32)
    y_exp = np.asarray(expand_activations(jnp.asarray(x), spec)) @ w_exp
    np.testing.assert_allclose(y_exp, x @ w, rtol=1e-4, atol=1e-5)


def test_qa_bias_split_preserves_quantization():
    """Activation QA split with bias ∓Δ/4: quantized halves sum to Q(x)."""
    delta = 0.125
    x = np.asarray([0.1875, -0.4375, 0.5, 1.0], dtype=np.float32)  # incl. midpoints
    x1 = x / 2 - delta / 4
    x2 = x / 2 + delta / 4
    np.testing.assert_allclose(_Q(x1, delta) + _Q(x2, delta), _Q(x, delta), atol=1e-7)


# ---------------------------------------------------------------------------
# Channel selection / overhead


def test_selects_global_max_channel():
    w = np.ones((8, 4), dtype=np.float32) * 0.1
    w[3, 2] = 50.0
    w_exp, spec, _ = split_weights(w, 1 / 8, 8)
    assert w_exp.shape[0] == 9
    assert int(spec.src[-1]) == 3


def test_iterative_resplit_of_same_channel():
    """A huge outlier channel should be split repeatedly (§3.4: one at a time)."""
    w = np.full((8, 4), 0.01, dtype=np.float32)
    w[0, 0] = 100.0
    w_exp, spec, _ = split_weights(w, 3 / 8, 8)
    assert w_exp.shape[0] == 11
    # All three splits should trace back to channel 0.
    assert np.all(np.asarray(spec.src[8:]) == 0)
    # Three binary splits of the 100.0 outlier bring the max near 100/4.
    assert np.abs(w_exp).max() < 30.0


def test_n_splits_ceil():
    assert n_splits_for_ratio(100, 0.01) == 1
    assert n_splits_for_ratio(100, 0.015) == 2
    assert n_splits_for_ratio(64, 0.05) == 4
    assert n_splits_for_ratio(64, 0.0) == 0


@settings(max_examples=50, deadline=None)
@given(
    st.integers(min_value=4, max_value=64),
    st.floats(min_value=0.0, max_value=0.3),
)
def test_overhead_matches_ratio(c, r):
    """Table 5: relative size overhead == ceil(r*C)/C ~= r."""
    rng = np.random.default_rng(0)
    w = rng.normal(size=(c, 4)).astype(np.float32)
    w_exp, spec, _ = split_weights(w, r, 8)
    n = n_splits_for_ratio(c, r)
    assert w_exp.shape[0] == c + n
    assert spec.n_expanded == c + n


# ---------------------------------------------------------------------------
# QA vs naive end-to-end quantization error (Table 1 mechanism)


def test_qa_no_worse_than_naive_quant_error():
    rng = np.random.default_rng(3)
    w = rng.laplace(size=(64, 64)).astype(np.float32)
    errs = {}
    for qa in (True, False):
        w_exp, spec, thresh = split_weights(w, 0.1, 4, qa=qa)
        wq = np.asarray(fake_quant(jnp.asarray(w_exp), 4, clip=thresh))
        w_eff, _ = collapse_expanded(wq, spec, 64)
        errs[qa] = float(((w_eff - w) ** 2).mean())
    assert errs[True] <= errs[False] * 1.05  # QA at least matches naive


def test_ocs_reduces_dynamic_range():
    """Splitting the max channel must shrink max|w| (the whole point of OCS)."""
    rng = np.random.default_rng(4)
    w = rng.normal(size=(128, 32)).astype(np.float32)
    w[11] *= 8.0
    w_exp, _, _ = split_weights(w, 0.02, 8)
    assert np.abs(w_exp).max() < np.abs(w).max() * 0.75


# ---------------------------------------------------------------------------
# Oracle OCS


def test_oracle_expand_equivalence_and_selection():
    rng = np.random.default_rng(5)
    c = 16
    x = rng.normal(size=(8, c)).astype(np.float32)
    x[:, 4] *= 20.0
    w = rng.normal(size=(c, 6)).astype(np.float32)
    x_exp, src = oracle_expand(jnp.asarray(x), 2)
    assert x_exp.shape == (8, c + 2)
    assert 4 in np.asarray(src[c:])  # the batch outlier channel selected
    w_exp = jnp.take(jnp.asarray(w), src, axis=0)
    np.testing.assert_allclose(
        np.asarray(x_exp @ w_exp), x @ w, rtol=1e-4, atol=1e-4
    )
    # Expanded max is halved relative to the original outlier.
    assert float(jnp.abs(x_exp).max()) < np.abs(x).max() * 0.75


# ---------------------------------------------------------------------------
# Full pipeline object


def test_make_ocs_quant_linear_pipeline():
    rng = np.random.default_rng(6)
    w = rng.normal(size=(40, 24)).astype(np.float32)
    w[3] *= 6.0
    lin = make_ocs_quant_linear(w, 0.05, 8, clip_method="mse", pad_to=8)
    assert lin.weight.values.shape[0] % 8 == 0
    x = rng.normal(size=(4, 40)).astype(np.float32)
    y = np.asarray(
        expand_activations(jnp.asarray(x), lin.spec) @ lin.dequant_weight()
    )
    rel = np.abs(y - x @ w).max() / np.abs(x @ w).max()
    assert rel < 0.05  # 8-bit + OCS: small relative error end-to-end
