"""Data pipeline, checkpointing, runtime health, serving engine, launchers."""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import smoke_config
from repro.data import CalibrationSampler, DataState, SyntheticLM, make_batch_iterator
from repro.models import transformer as T
from repro.runtime.health import HeartbeatMonitor, StepTimer
from repro.serving import EngineConfig, Request, ServingEngine

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# data pipeline


def test_data_determinism_and_restart():
    ds = SyntheticLM(256, 32, 8, seed=3)
    b1 = ds.batch_at(5)
    b2 = ds.batch_at(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # labels are tokens shifted by one
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])
    # iterator resume == fresh iterator at the same step
    it = make_batch_iterator(ds, DataState(seed=3, step=0))
    for _ in range(4):
        state, batch = next(it)
    it2 = make_batch_iterator(ds, DataState(seed=3, step=3))
    _, batch2 = next(it2)
    np.testing.assert_array_equal(batch["tokens"], batch2["tokens"])


def test_data_host_sharding_partitions_global_batch():
    ds = SyntheticLM(128, 16, 8, seed=1)
    full = ds.batch_at(2)["tokens"]
    parts = [
        ds.batch_at(2, host_id=h, n_hosts=4)["tokens"] for h in range(4)
    ]
    np.testing.assert_array_equal(np.concatenate(parts, 0), full)


def test_data_is_learnable_structure():
    """The bigram chain must dominate: next token is predictable >50% of steps."""
    ds = SyntheticLM(64, 256, 2, seed=0, noise_p=0.15)
    b = ds.batch_at(0)
    pred = (ds.a * b["tokens"] + ds.b) % ds.vocab
    acc = (pred == b["labels"]).mean()
    assert acc > 0.7, acc


def test_calibration_sampler_replays_training_batches():
    ds = SyntheticLM(64, 16, 4, seed=0)
    batches = list(CalibrationSampler(ds, n_batches=3))
    assert len(batches) == 3
    np.testing.assert_array_equal(batches[1]["tokens"], ds.batch_at(1)["tokens"])


# ---------------------------------------------------------------------------
# checkpointing


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "w": jax.random.normal(k, (8, 4)),
        "nested": {"b": jnp.arange(5, dtype=jnp.int32)},
    }


def test_checkpoint_roundtrip_and_keep_k(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_write=False)
    for s in (10, 20, 30):
        mgr.save(s, _tree(s), meta={"data": {"seed": 0, "step": s}})
    assert mgr.all_steps() == [20, 30]  # keep-2 retention
    restored, meta = mgr.restore(_tree())
    assert meta["data"]["step"] == 30
    np.testing.assert_allclose(restored["w"], _tree(30)["w"], rtol=1e-6)


def test_checkpoint_async_and_wait(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3, async_write=True)
    for s in range(3):
        mgr.save(s, _tree(s))
    mgr.wait()
    assert mgr.all_steps() == [0, 1, 2]
    mgr.close()


def test_checkpoint_atomicity_partial_write_ignored(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3, async_write=False)
    mgr.save(1, _tree(1), meta={"ok": True})
    # Simulate a crash mid-write: a stale .tmp directory with garbage.
    os.makedirs(tmp_path / "step_00000002.tmp")
    (tmp_path / "step_00000002.tmp" / "a00000.npy").write_bytes(b"partial")
    assert mgr.latest_step() == 1  # .tmp is invisible to readers
    # A new manager (fresh process) clears the partial write.
    mgr2 = CheckpointManager(str(tmp_path), async_write=False)
    assert not any(n.endswith(".tmp") for n in os.listdir(tmp_path))
    restored, meta = mgr2.restore(_tree())
    assert meta["ok"] is True


def test_checkpoint_shape_mismatch_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_write=False)
    mgr.save(1, _tree())
    bad = {"w": jnp.zeros((9, 4)), "nested": {"b": jnp.zeros(5, jnp.int32)}}
    with pytest.raises(ValueError, match="stored shape"):
        mgr.restore(bad)


def test_checkpoint_elastic_reshard(tmp_path):
    """Mesh-agnostic restore: save unsharded, re-place on a different mesh."""
    from repro.launch.mesh import make_debug_mesh
    from repro.runtime import reshard_tree
    from repro.sharding.specs import SINGLE_POD_RULES

    mgr = CheckpointManager(str(tmp_path), async_write=False)
    tree = {"layers": {"mlp": {"w1": jnp.ones((8, 4))}}}
    mgr.save(5, tree)
    restored, _ = mgr.restore(tree)
    mesh = make_debug_mesh(1, 1)  # "new topology" (1 device in-container)
    placed = reshard_tree(
        jax.tree.map(jnp.asarray, restored), mesh, SINGLE_POD_RULES
    )
    np.testing.assert_array_equal(placed["layers"]["mlp"]["w1"], tree["layers"]["mlp"]["w1"])


# ---------------------------------------------------------------------------
# runtime health


def test_step_timer_flags_stragglers():
    t = StepTimer(window=10, factor=1.5, patience=2)
    import time as _time

    for _ in range(5):
        t.start(); _time.sleep(0.001); t.stop()
    assert not t.is_straggling
    for _ in range(2):
        t.start(); _time.sleep(0.02); t.stop()
    assert t.is_straggling


def test_heartbeat_roundtrip(tmp_path):
    hb = HeartbeatMonitor(str(tmp_path / "hb.json"), host_id=7, timeout=0.05)
    hb.beat(42, {"loss": 1.0})
    rec = hb.read()
    assert rec["host"] == 7 and rec["step"] == 42
    import time as _time

    _time.sleep(0.06)
    assert hb.stale_hosts([str(tmp_path / "hb.json")]) == [7]


def test_heartbeat_stale_hosts_unreadable(tmp_path):
    """Missing/corrupt/field-less heartbeat files report host -1 (presumed
    dead) rather than raising — the watchdog must survive torn writes."""
    hb = HeartbeatMonitor(str(tmp_path / "hb.json"), timeout=60.0)
    missing = str(tmp_path / "never-written.json")
    corrupt = str(tmp_path / "corrupt.json")
    with open(corrupt, "w") as f:
        f.write("{not json")
    no_field = str(tmp_path / "nofield.json")
    with open(no_field, "w") as f:
        f.write('{"host": 3}')  # no "time" key
    hb.beat(1)
    assert hb.stale_hosts([missing, corrupt, no_field, hb.path]) == [-1, -1, -1]


def test_heartbeat_throttle(tmp_path):
    """min_interval suppresses writes landing inside the window; force=True
    bypasses it so a drain's final beat always reaches the file."""
    hb = HeartbeatMonitor(str(tmp_path / "hb.json"), min_interval=60.0)
    for step in range(5):
        hb.beat(step)
    assert hb.beats == 5 and hb.writes == 1
    assert hb.read()["step"] == 0  # only the first beat landed
    hb.beat(99, force=True)
    assert hb.writes == 2
    assert hb.read()["step"] == 99


def test_heartbeat_no_throttle_by_default(tmp_path):
    """min_interval=0.0 keeps the legacy write-every-beat behavior."""
    hb = HeartbeatMonitor(str(tmp_path / "hb.json"))
    for step in range(5):
        hb.beat(step)
    assert hb.writes == 5
    assert hb.read()["step"] == 4


# ---------------------------------------------------------------------------
# gradient compression (error feedback)


def test_compressed_psum_single_pod_error_feedback():
    """On a 1-device 'pod' axis: compression error must be re-sent next step."""
    from repro.launch.mesh import make_debug_mesh
    from repro.runtime import compressed_psum, init_compression

    mesh = jax.make_mesh((1,), ("pod",))
    g = {"w": jnp.asarray([[0.3, -1.7, 0.004, 2.5]], jnp.float32)}
    state = init_compression(g)
    total = jnp.zeros_like(g["w"])
    exact = jnp.zeros_like(g["w"])
    for _ in range(50):
        out, state = compressed_psum(mesh, g, state, axis="pod")
        total = total + out["w"]
        exact = exact + g["w"]
    # Error feedback: accumulated compressed sum tracks the exact sum to
    # within ONE quantization step (not 50 of them).
    step = float(jnp.max(jnp.abs(g["w"] + state.residual["w"]))) / 127.0
    assert float(jnp.max(jnp.abs(total - exact))) <= step + 1e-6


# ---------------------------------------------------------------------------
# serving engine (smoke config)


def test_serving_engine_continuous_batching():
    cfg = smoke_config("deepseek-7b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, EngineConfig(max_batch=2, max_len=64))
    rng = np.random.default_rng(0)
    reqs = [
        Request(uid=i, prompt=rng.integers(0, cfg.vocab, 5).tolist(),
                max_new_tokens=4)
        for i in range(5)  # 5 requests > 2 slots -> forces slot reuse
    ]
    for r in reqs:
        eng.submit(r)
    done = eng.run()
    assert len(done) == 5
    for r in done:
        assert len(r.output) == 4
        assert all(0 <= t < cfg.vocab for t in r.output)
    s = eng.stats()
    assert s["completed"] == 5 and s["decoded_tokens"] >= 15


def test_serving_engine_quantized_params():
    from repro.core.apply import quantize_params
    from repro.core.recipe import QuantRecipe

    cfg = smoke_config("qwen3-14b")
    params = T.init_params(cfg, jax.random.PRNGKey(1))
    qparams = quantize_params(params, QuantRecipe(w_bits=8, ocs_ratio=0.02))
    eng = ServingEngine(cfg, qparams, EngineConfig(max_batch=2, max_len=32))
    eng.submit(Request(uid=0, prompt=[1, 2, 3], max_new_tokens=3))
    done = eng.run()
    assert len(done) == 1 and len(done[0].output) == 3


# ---------------------------------------------------------------------------
# launchers end-to-end (subprocess: checkpoint/restart drill)


@pytest.mark.slow
def test_train_launcher_failure_restart(tmp_path):
    """Kill at step 6, restart, verify identical final state vs uninterrupted."""
    env = {**os.environ, "PYTHONPATH": os.path.join(REPO, "src")}
    ckpt_a = str(tmp_path / "a")
    base = [sys.executable, "-m", "repro.launch.train", "--arch", "deepseek-7b",
            "--smoke", "--steps", "10", "--batch", "2", "--seq", "32",
            "--ckpt-every", "3", "--log-every", "50"]
    # Uninterrupted run.
    r = subprocess.run(base + ["--ckpt-dir", ckpt_a], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-2000:]
    # Interrupted at step 6 (checkpoint exists at step 6), then resumed.
    ckpt_b = str(tmp_path / "b")
    r1 = subprocess.run(base + ["--ckpt-dir", ckpt_b, "--simulate-failure", "6"],
                        env=env, capture_output=True, text=True, timeout=600)
    assert r1.returncode == 1
    r2 = subprocess.run(base + ["--ckpt-dir", ckpt_b], env=env,
                        capture_output=True, text=True, timeout=600)
    assert r2.returncode == 0, r2.stderr[-2000:]
    assert "restored step 6" in r2.stdout

    a = CheckpointManager(ckpt_a, async_write=False)
    b = CheckpointManager(ckpt_b, async_write=False)
    assert a.latest_step() == b.latest_step() == 10
    cfg = smoke_config("deepseek-7b")
    import repro.optim as optim

    template = (T.init_params(cfg, jax.random.PRNGKey(0)), None)
    pa, _ = a.restore((template[0],))
    pb, _ = b.restore((template[0],))
    flat_a = jax.tree_util.tree_leaves(pa)
    flat_b = jax.tree_util.tree_leaves(pb)
    for xa, xb in zip(flat_a, flat_b):
        np.testing.assert_allclose(np.asarray(xa), np.asarray(xb), atol=1e-6)
