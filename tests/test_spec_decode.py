"""Self-speculative decoding (ISSUE 3): draft, multi-token verify, rollback.

The acceptance bar: greedy spec-decode is **token-identical** to plain greedy
decode (dense + MoE, paged + unpaged engines, eos mid-window, budget boundary
inside an accepted window) — every committed token comes from the target's
own argmax, so the draft can only change *how fast* tokens commit, never
*which* tokens. And the paged-KV rollback invariant: speculation leaves the
page allocator (refcounts, pool occupancy, prefix cache) in exactly the state
of never having speculated.
"""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import smoke_config
from repro.models import transformer as T
from repro.serving import (EngineConfig, PageAllocator, Request,
                           ServingEngine, SpecConfig)
from repro.serving.spec_decode import AdaptiveK, committed_tokens


@pytest.fixture(scope="module")
def dense_setup():
    cfg = smoke_config("glm4-9b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


@pytest.fixture(scope="module")
def quant_setup(dense_setup):
    from repro.core.apply import quantize_params
    from repro.core.recipe import QuantRecipe

    cfg, params = dense_setup
    qparams = quantize_params(
        params, QuantRecipe(w_bits=8, ocs_ratio=0.02, per_channel=True, pad_to=1)
    )
    return cfg, qparams


def _run(cfg, params, prompts, *, max_new=6, spec=None, paged=None,
         max_batch=3, max_len=64, matmul_mode="dequant", eos=None):
    eng = ServingEngine(
        cfg, params,
        EngineConfig(max_batch=max_batch, max_len=max_len, paged=paged,
                     matmul_mode=matmul_mode, spec=spec),
    )
    for i, p in enumerate(prompts):
        eng.submit(Request(uid=i, prompt=list(p), max_new_tokens=max_new, eos_id=eos))
    done = {r.uid: r.output for r in eng.run()}
    return done, eng


# ---------------------------------------------------------------------------
# Model layer: the multi-token verify path


@pytest.mark.parametrize("paged", [False, True])
def test_verify_step_equals_sequential_decode(paged):
    """verify_step's Q logits are bit-identical to Q sequential one-token
    decode steps — the primitive the exactness contract rests on (float
    caches; the paged run addresses the same positions through a table)."""
    cfg = smoke_config("deepseek-7b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)
    tokens = rng.randint(0, cfg.vocab, (2, 5))
    B, L, ps = 2, 32, 8

    def mk_caches():
        if not paged:
            return T.init_cache(cfg, B, L, dtype=jnp.float32)
        from repro.serving import kv_cache as kvc

        t = L // ps
        caches = kvc.init_paged_cache(cfg, B, B * t + 1, ps, t, dtype=jnp.float32)
        table = np.arange(1, B * t + 1, dtype=np.int32).reshape(B, t)
        caches["table"] = jnp.asarray(table)
        return caches

    caches = mk_caches()
    outs = []
    for i in range(tokens.shape[1]):
        lg, caches = T.decode_step(
            params, jnp.asarray(tokens[:, i : i + 1]), caches, cfg
        )
        outs.append(np.asarray(lg, np.float32))
    seq = np.stack(outs, axis=1)

    caches = mk_caches()
    lg, caches = T.verify_step(params, jnp.asarray(tokens), caches, cfg)
    np.testing.assert_array_equal(seq, np.asarray(lg, np.float32))
    assert int(caches["pos"][0]) == tokens.shape[1]


def test_truncated_draft_runs_prefix_only():
    """layers_limit: the drafter runs the first L layers (different logits)
    and leaves the skipped layers' caches untouched."""
    cfg = smoke_config("glm4-9b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    caches = T.init_cache(cfg, 1, 32, dtype=jnp.float32)
    tok = jnp.asarray([[37]], jnp.int32)
    lg_full, _ = T.decode_step(params, tok, caches, cfg)
    lg_tr, c2 = T.decode_step(params, tok, caches, cfg, layers_limit=1)
    assert float(np.abs(np.asarray(lg_full) - np.asarray(lg_tr)).max()) > 0
    for a, b in zip(
        jax.tree_util.tree_leaves(caches["layers"][-1]),
        jax.tree_util.tree_leaves(c2["layers"][-1]),
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_multi_token_decode_rejects_ssm():
    cfg = smoke_config("mamba2-1.3b")
    params = T.init_params(cfg, jax.random.PRNGKey(1))
    caches = T.init_cache(cfg, 1, 16, dtype=jnp.float32)
    with pytest.raises(NotImplementedError):
        T.verify_step(params, jnp.zeros((1, 3), jnp.int32), caches, cfg)


# ---------------------------------------------------------------------------
# Exactness contract: spec greedy == plain greedy


@pytest.mark.parametrize("paged", [True, False])
def test_spec_matches_plain_greedy_dense_quantized(quant_setup, paged):
    """The contract, on a *real* draft/target split: int8 weights served in
    dequant mode (target) with the w8a8 dynamic-quant path drafting. Drafts
    get rejected (acceptance < 1) yet the output stream is token-identical."""
    cfg, qparams = quant_setup
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, cfg.vocab, n).tolist() for n in [3, 11, 6, 21]]
    plain, _ = _run(cfg, qparams, prompts, max_new=8, paged=paged)
    spec, eng = _run(
        cfg, qparams, prompts, max_new=8, paged=paged,
        spec=SpecConfig(k=3, draft_mode="w8a8"),
    )
    assert spec == plain
    s = eng.stats()
    assert s["spec_rounds"] > 0 and s["spec_proposed"] > 0
    assert 0.0 < s["spec_acceptance_rate"] <= 1.0
    # Each target step commits at least its correction token.
    assert s["spec_tokens_per_target_step"] >= 1.0


def test_spec_matches_plain_greedy_moe_paged():
    """MoE target: expert routing is stateless, so verify batches Q tokens
    through the same dispatch — spec must stay token-identical there too."""
    cfg = smoke_config("deepseek-moe-16b")
    params = T.init_params(cfg, jax.random.PRNGKey(1))
    rng = np.random.default_rng(2)
    prompts = [rng.integers(0, cfg.vocab, n).tolist() for n in [4, 13]]
    plain, _ = _run(cfg, params, prompts, max_new=6, max_batch=2)
    spec, eng = _run(
        cfg, params, prompts, max_new=6, max_batch=2,
        spec=SpecConfig(k=3, draft_layers=1),
    )
    assert spec == plain
    assert eng.stats()["spec_rounds"] > 0


def test_spec_identical_draft_accepts_everything(dense_setup):
    """Float params: every matmul mode is the float matmul, so the draft IS
    the target — acceptance must be exactly 1.0 (the window clamp keeps
    beyond-budget drafts out of the rate)."""
    cfg, params = dense_setup
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab, n).tolist() for n in [5, 9]]
    plain, _ = _run(cfg, params, prompts, max_new=7, max_batch=2)
    spec, eng = _run(
        cfg, params, prompts, max_new=7, max_batch=2, spec=SpecConfig(k=3)
    )
    assert spec == plain
    s = eng.stats()
    assert s["spec_acceptance_rate"] == 1.0
    # Full acceptance: fewer target steps than tokens generated.
    assert s["decode_steps"] < s["decoded_tokens"]


def test_spec_eos_mid_window(quant_setup):
    """eos landing inside an accepted window retires the lane with the tail
    dropped — same tokens as the plain engine honoring the same eos."""
    cfg, qparams = quant_setup
    rng = np.random.default_rng(17)
    prompts = [rng.integers(0, cfg.vocab, 9).tolist()]
    # Probe a full greedy run, then pick a mid-stream token as the eos.
    probe, _ = _run(cfg, qparams, prompts, max_new=10, max_batch=1)
    eos = probe[0][len(probe[0]) // 2]
    plain, _ = _run(cfg, qparams, prompts, max_new=10, max_batch=1, eos=eos)
    spec, eng = _run(
        cfg, qparams, prompts, max_new=10, max_batch=1, eos=eos,
        spec=SpecConfig(k=3, draft_mode="w8a8"),
    )
    assert spec == plain
    assert spec[0][-1] == eos and len(spec[0]) < 10
    assert eng.stats()["kv_pages_in_use"] == 0  # retired mid-window: reclaimed


@pytest.mark.parametrize("max_new", [2, 3, 4, 5])
def test_spec_max_new_boundary_inside_window(dense_setup, max_new):
    """The budget boundary lands at every offset inside an accepted window
    (float params, k=3: windows commit up to 4 tokens) — the output must cut
    exactly at max_new_tokens, identical to the plain engine."""
    cfg, params = dense_setup
    rng = np.random.default_rng(23)
    prompts = [rng.integers(0, cfg.vocab, 6).tolist()]
    plain, _ = _run(cfg, params, prompts, max_new=max_new, max_batch=1)
    spec, _ = _run(
        cfg, params, prompts, max_new=max_new, max_batch=1,
        spec=SpecConfig(k=3, adaptive=False),
    )
    assert spec == plain and len(spec[0]) == max_new


def test_spec_mixed_continuous_batching(quant_setup):
    """Hot-swap admission under speculation: more requests than lanes, mixed
    lengths/budgets — all complete, all token-identical to plain serving."""
    cfg, qparams = quant_setup
    rng = np.random.default_rng(29)
    prompts = [rng.integers(0, cfg.vocab, int(n)).tolist()
               for n in rng.integers(3, 24, size=6)]
    plain, _ = _run(cfg, qparams, prompts, max_new=5, max_batch=2)
    spec, eng = _run(
        cfg, qparams, prompts, max_new=5, max_batch=2,
        spec=SpecConfig(k=3, draft_mode="w8a8"),
    )
    assert spec == plain and len(spec) == 6


# ---------------------------------------------------------------------------
# Rollback invariant: the allocator can't tell speculation ever happened


def test_spec_rollback_allocator_state_matches_plain(quant_setup):
    """After draining the same workload, the speculative engine's page pool
    is indistinguishable from the plain engine's: zero referenced pages, the
    same free+cached accounting, the same request footprints — rollback
    releases nothing it shouldn't and leaks nothing it wrote."""
    cfg, qparams = quant_setup
    rng = np.random.default_rng(31)
    prompts = [rng.integers(0, cfg.vocab, n).tolist() for n in [17, 5, 33, 12]]
    _, eng_p = _run(cfg, qparams, prompts, max_new=6)
    _, eng_s = _run(
        cfg, qparams, prompts, max_new=6, spec=SpecConfig(k=3, draft_mode="w8a8")
    )
    a_p, a_s = eng_p.allocator, eng_s.allocator
    assert a_s.in_use() == a_p.in_use() == 0
    assert a_s._ref == a_p._ref == {}  # no stray refcounts
    assert a_s.available() == a_p.available() == a_s.capacity
    assert a_s.cached_pages() == a_p.cached_pages()
    assert a_s.peak_in_use == a_p.peak_in_use  # same footprint per request


def test_allocator_truncate():
    """Page-aware truncate: releases exactly the tail past the committed
    token count; registered (prefix-cache) pages drop to the LRU and stay
    hit-able — truncation keeps the prefix cache consistent."""
    a = PageAllocator(n_pages=8, page_size=4)
    ids = a.alloc(5)  # covers 20 tokens
    kept = a.truncate(ids, 10)  # 10 tokens -> 3 pages
    assert kept == ids[:3] and a.in_use() == 3 and a.available() == 4
    assert a.truncate(kept, 12) == kept  # nothing past the committed point
    # Registered prompt page released by truncate stays hit-able.
    key = a.chain_keys([1, 2, 3, 4], 1)[0]
    a.register(key, kept[0])
    assert a.truncate(kept, 0) == []
    assert a.in_use() == 0 and a.cached_pages() == 1
    hits, _ = a.match_prefix([1, 2, 3, 4], max_pages=1)
    assert hits == [kept[0]]


def test_spec_requires_attention_arch():
    cfg = smoke_config("mamba2-1.3b")
    params = T.init_params(cfg, jax.random.PRNGKey(1))
    with pytest.raises(ValueError):
        ServingEngine(cfg, params,
                      EngineConfig(max_batch=1, max_len=32, spec=SpecConfig(k=3)))


def test_spec_submit_rejects_overlong_budget(dense_setup):
    """Spec engines require prompt + max_new_tokens <= max_len: committed
    positions must live in real cache slots for the exactness contract."""
    cfg, params = dense_setup
    eng = ServingEngine(cfg, params,
                        EngineConfig(max_batch=1, max_len=32, spec=SpecConfig(k=2)))
    with pytest.raises(ValueError):
        eng.submit(Request(uid=0, prompt=list(range(20)), max_new_tokens=20))


# ---------------------------------------------------------------------------
# Adaptive window controller + bookkeeping units


def test_committed_tokens_accept_prefix():
    # full accept: all drafts match the target chain -> k + 1 commits
    toks, acc = committed_tokens([7, 8, 9], [7, 8, 9, 4], k=3)
    assert toks == [7, 8, 9, 4] and acc == 3
    # first miss at j=1: commit the match + the target's correction
    toks, acc = committed_tokens([7, 5, 9], [7, 8, 9, 4], k=3)
    assert toks == [7, 8] and acc == 1
    # immediate miss: the round still commits the target's token
    toks, acc = committed_tokens([5, 5, 5], [7, 8, 9, 4], k=3)
    assert toks == [7] and acc == 0
    # k == 0: a plain decode step through the verify path
    toks, acc = committed_tokens([], [7], k=0)
    assert toks == [7] and acc == 0


def test_adaptive_k_grows_and_shrinks():
    spec = SpecConfig(k=5, k_min=1, grow_at=0.8, shrink_at=0.4, ema=0.5)
    ctl = AdaptiveK(spec)
    k0 = ctl.k
    for _ in range(10):
        ctl.update(accepted=10, proposed=10)  # perfect drafts
    assert ctl.k == 5 > k0
    for _ in range(20):
        ctl.update(accepted=0, proposed=10)  # hopeless drafts
    assert ctl.k == 1
    ctl.update(accepted=0, proposed=0)  # no usable proposals: k unchanged
    assert ctl.k == 1
    fixed = AdaptiveK(SpecConfig(k=4, adaptive=False))
    assert fixed.k == 4
    fixed.update(accepted=0, proposed=10)
    assert fixed.k == 4  # non-adaptive: pinned


def test_spec_stats_schema(dense_setup):
    cfg, params = dense_setup
    done, eng = _run(
        cfg, params, [[1, 2, 3], [4, 5, 6, 7]], max_new=5, max_batch=2,
        spec=SpecConfig(k=2),
    )
    s = eng.stats()
    for key in (
        "spec_enabled", "spec_rounds", "spec_k", "spec_proposed",
        "spec_accepted", "spec_acceptance_rate", "spec_tokens_per_target_step",
        "spec_draft_time_s", "spec_verify_time_s", "spec_compile_s",
    ):
        assert key in s, key
    assert s["spec_enabled"] == 1.0
    # 2 requests x (max_new - 1) decode-committed tokens (first from prefill)
    assert s["decoded_tokens"] == 8
    assert all(len(o) == 5 for o in done.values())
    # decode_steps now counts target steps: fewer than decoded tokens.
    assert s["decode_steps"] <= s["decoded_tokens"]
