"""Shared test setup.

The container does not ship ``hypothesis``; the property tests only use a
small slice of its API, so a deterministic stub (``_hypothesis_stub``) is
installed into ``sys.modules`` before collection when the real package is
missing. With the real package installed the stub is inert.
"""
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running test (make test-fast skips)")


try:  # pragma: no cover - exercised only where hypothesis exists
    import hypothesis  # noqa: F401
except ImportError:
    import _hypothesis_stub

    sys.modules["hypothesis"] = _hypothesis_stub
    sys.modules["hypothesis.strategies"] = _hypothesis_stub.strategies
