"""MoE shard-local dispatch: multi-device equivalence (subprocess).

The shard_map dispatch path must produce the same outputs as the
single-device reference on a real multi-device mesh (2 data x 2 model, with
experts split across the model axis and tokens across the data axis).
Runs in a subprocess because the 8-device XLA flag must be set before jax
initializes.
"""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import smoke_config
    from repro.models import moe as moe_mod
    from repro.models import transformer as T
    from repro.sharding.specs import SINGLE_POD_RULES, use_rules

    import dataclasses
    cfg = smoke_config("deepseek-moe-16b")  # 8 experts top-3, 1 shared
    # Capacity high enough that nothing drops: the sharded path enforces
    # capacity per (data-shard, expert) while the reference is global, so
    # only the drop-free regime is bit-comparable.
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    layer0 = jax.tree.map(lambda a: a[0], params["layers"])["moe"]
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, cfg.d_model), jnp.float32)

    ref = moe_mod._moe_local(layer0, x.reshape(-1, cfg.d_model), cfg)
    if "shared" in layer0:
        sh = layer0["shared"]
        xf = x.reshape(-1, cfg.d_model)
        g = xf @ sh["w_gate"]; u = xf @ sh["w_up"]
        ref = ref + (jax.nn.silu(g) * u) @ sh["w_down"]
    ref = ref.reshape(x.shape)

    mesh = jax.make_mesh((2, 2), ("data", "model"))
    with use_rules(mesh, SINGLE_POD_RULES):
        out = moe_mod.moe(layer0, x, cfg)
    d = float(jnp.abs(out - ref).max())
    print("MAXDIFF", d)
    assert d < 2e-5, d

    # Gradient path: shard_map backward (psum -> identity, all_gather ->
    # reduce-scatter) must be finite and nonzero.
    def loss(p):
        with use_rules(mesh, SINGLE_POD_RULES):
            return jnp.sum(moe_mod.moe(p, x, cfg) ** 2)
    g = jax.grad(loss)(layer0)
    gn = sum(float(jnp.sum(v * v)) for v in jax.tree.leaves(g))
    assert np.isfinite(gn) and gn > 0, gn
    print("GRAD_OK", gn)

    # Quantized (serving) expert tree through the same shard_map dispatch.
    from repro.core.apply import quantize_params
    from repro.core.recipe import QuantRecipe
    q0 = quantize_params({"moe": layer0}, QuantRecipe(w_bits=8, ocs_ratio=0.05,
                                                      pad_to=16))["moe"]
    ref_q = moe_mod.moe(q0, x, cfg)  # no mesh -> local path
    with use_rules(mesh, SINGLE_POD_RULES):
        out_q = moe_mod.moe(q0, x, cfg)
    dq = float(jnp.abs(out_q - ref_q).max())
    assert dq < 2e-5, dq
    print("QUANT_OK", dq)
""")


@pytest.mark.slow
def test_moe_shardmap_multidevice_equivalence():
    env = {**os.environ, "PYTHONPATH": os.path.join(REPO, "src")}
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    assert "MAXDIFF" in r.stdout and "GRAD_OK" in r.stdout
