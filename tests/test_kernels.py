"""Pallas kernels vs pure-jnp oracles (interpret mode = kernel body on CPU).

Every kernel is swept over shapes (aligned and ragged), dtypes, and scale
granularities, and asserted allclose against repro.kernels.ref. Integer paths
must match bit-exactly; float paths allow accumulation-order tolerance.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.dynamic_quant import dynamic_quant
from repro.kernels.ocs_matmul import ocs_quant_matmul
from repro.kernels.quant_matmul import quant_matmul

RNG = np.random.RandomState(0)


def _i8(*shape):
    return jnp.asarray(RNG.randint(-127, 128, shape), jnp.int8)


def _f(*shape, dtype=jnp.float32):
    return jnp.asarray(RNG.randn(*shape), dtype)


# ---------------------------------------------------------------------------
# quant_matmul


@pytest.mark.parametrize("m,k,n", [(128, 128, 128), (256, 384, 128), (8, 128, 72), (200, 260, 130)])
@pytest.mark.parametrize("per_channel", [False, True])
def test_quant_matmul_w8a8(m, k, n, per_channel):
    x8, w8 = _i8(m, k), _i8(k, n)
    xs = jnp.asarray(RNG.rand(m) + 0.1, jnp.float32)
    ws = jnp.asarray(RNG.rand(n) + 0.1, jnp.float32) if per_channel \
        else jnp.asarray(0.37, jnp.float32)
    got = quant_matmul(x8, w8, ws, xs, interpret=True)
    want = ref.quant_matmul_ref(x8, w8, xs, jnp.broadcast_to(ws, (n,)))
    np.testing.assert_allclose(got, want, rtol=1e-6)


@pytest.mark.parametrize("m,k,n", [(128, 128, 128), (64, 256, 192)])
@pytest.mark.parametrize("xdtype", [jnp.float32, jnp.bfloat16])
def test_quant_matmul_weight_only(m, k, n, xdtype):
    x = _f(m, k, dtype=xdtype)
    w8 = _i8(k, n)
    ws = jnp.asarray(RNG.rand(n) + 0.1, jnp.float32)
    got = quant_matmul(x, w8, ws, interpret=True, out_dtype=jnp.float32)
    want = (
        x.astype(jnp.float32) @ w8.astype(jnp.float32) * ws[None, :]
    )
    # Blocked-K accumulation reassociates float sums: tolerance, not exactness.
    np.testing.assert_allclose(got, want, rtol=2e-2 if xdtype == jnp.bfloat16 else 2e-3)


@settings(max_examples=15, deadline=None)
@given(
    m=st.integers(1, 70),
    k=st.integers(1, 80),
    n=st.integers(1, 70),
)
def test_quant_matmul_w8a8_property(m, k, n):
    """Bit-exactness for arbitrary ragged shapes (padding correctness)."""
    rng = np.random.RandomState(m * 7919 + k * 131 + n)
    x8 = jnp.asarray(rng.randint(-127, 128, (m, k)), jnp.int8)
    w8 = jnp.asarray(rng.randint(-127, 128, (k, n)), jnp.int8)
    xs = jnp.asarray(rng.rand(m) + 0.05, jnp.float32)
    ws = jnp.asarray(rng.rand(n) + 0.05, jnp.float32)
    got = quant_matmul(x8, w8, ws, xs, interpret=True, bm=32, bn=32, bk=32)
    want = ref.quant_matmul_ref(x8, w8, xs, ws)
    np.testing.assert_allclose(got, want, rtol=1e-6)


# ---------------------------------------------------------------------------
# dynamic_quant


@pytest.mark.parametrize("m,k", [(128, 512), (130, 96), (1, 2048), (256, 1600)])
@pytest.mark.parametrize("bits", [8, 6, 4])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dynamic_quant(m, k, bits, dtype):
    x = _f(m, k, dtype=dtype) * 3.0
    q, s = dynamic_quant(x, bits=bits, interpret=True)
    # Jit the oracle: interpret mode jits the kernel body, and XLA's
    # divide->reciprocal rewrite flips exact .5 midpoints by one ulp if the
    # two sides are compiled differently.
    q_ref, s_ref = jax.jit(ref.dynamic_quant_ref, static_argnums=1)(x, bits)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(q_ref))
    np.testing.assert_allclose(s, s_ref, rtol=1e-6)


def test_dynamic_quant_roundtrip_error_bound():
    """|x - dequant(q)| <= scale/2 per element (the linear-grid guarantee)."""
    x = _f(64, 300) * 10.0
    q, s = dynamic_quant(x, bits=8, interpret=True)
    err = np.abs(np.asarray(x) - np.asarray(q, np.float32) * np.asarray(s)[:, None])
    assert (err <= np.asarray(s)[:, None] * 0.5 + 1e-6).all()


# ---------------------------------------------------------------------------
# ocs_matmul (fused OCS expansion)


def _split_setup(rng, m, k, n, s):
    """Expanded weights [k+s, n] + tail sources, mimicking repro.core.ocs."""
    w8 = jnp.asarray(rng.randint(-127, 128, (k + s, n)), jnp.int8)
    src = jnp.asarray(rng.randint(0, k, (s,)), jnp.int32)
    ws = jnp.asarray(rng.rand(n) + 0.05, jnp.float32)
    return w8, src, ws


@pytest.mark.parametrize("m,k,n,s", [
    (128, 256, 128, 128),   # aligned, one tail block
    (64, 300, 130, 7),      # ragged everything
    (32, 128, 64, 0),       # no splits -> plain kernel fallback
    (256, 512, 256, 256),   # two tail blocks
])
def test_ocs_matmul_w8a8(m, k, n, s):
    rng = np.random.RandomState(42 + m + k + n + s)
    x8 = jnp.asarray(rng.randint(-127, 128, (m, k)), jnp.int8)
    w8, src, ws = _split_setup(rng, m, k, n, s)
    xs = jnp.asarray(rng.rand(m) + 0.05, jnp.float32)
    got = ocs_quant_matmul(x8, w8, ws, src, xs, interpret=True)
    want = ref.ocs_quant_matmul_ref(x8, w8, ws, src, xs)
    np.testing.assert_allclose(got, want, rtol=1e-6)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ocs_matmul_weight_only(dtype):
    rng = np.random.RandomState(7)
    m, k, n, s = 64, 256, 128, 16
    x = jnp.asarray(rng.randn(m, k), dtype)
    w8, src, ws = _split_setup(rng, m, k, n, s)
    mult = jnp.asarray(rng.choice([0.5, 1.0], s), jnp.float32)
    got = ocs_quant_matmul(
        x, w8, ws, src, tail_mult=mult, interpret=True, out_dtype=jnp.float32
    )
    want = ref.ocs_quant_matmul_ref(x, w8, ws, src, None, mult, jnp.float32)
    # Blocked-K accumulation reassociates float sums.
    np.testing.assert_allclose(
        got, want, rtol=2e-2 if dtype == jnp.bfloat16 else 2e-3
    )


def test_ocs_matmul_equals_materialized_dense():
    """The fused kernel == naive expand-then-matmul for a real OCS split."""
    from repro.core.ocs import make_ocs_quant_linear
    from repro.core.quantizer import dequantize

    rng = np.random.RandomState(3)
    k, n, m = 96, 80, 24
    w = rng.randn(k, n).astype(np.float32)
    w[rng.randint(0, k, 5), rng.randint(0, n, 5)] *= 8.0  # outliers
    lin = make_ocs_quant_linear(w, 0.05, 8, pad_to=32)
    x = jnp.asarray(rng.randn(m, k), jnp.float32)

    # Naive: materialize expanded activations (ref path used by layers.dense).
    from repro.core.ocs import expand_activations
    xe = expand_activations(x, lin.spec)
    want = xe @ lin.weight.dequant(jnp.float32)

    # Fused kernel: tail = spec entries beyond the original K channels.
    src_tail = lin.spec.src[k:]
    mult_tail = lin.spec.mult[k:]
    got = ocs_quant_matmul(
        x, lin.weight.values, lin.weight.scale, src_tail,
        tail_mult=mult_tail, interpret=True, out_dtype=jnp.float32,
    )
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(
    m=st.integers(1, 40),
    k=st.integers(8, 90),
    n=st.integers(1, 50),
    s=st.integers(0, 40),
)
def test_ocs_matmul_property(m, k, n, s):
    rng = np.random.RandomState(m + 100 * k + 7 * n + 13 * s)
    x8 = jnp.asarray(rng.randint(-127, 128, (m, k)), jnp.int8)
    w8, src, ws = _split_setup(rng, m, k, n, s)
    got = ocs_quant_matmul(x8, w8, ws, src, interpret=True, bm=32, bn=32, bk=32)
    want = ref.ocs_quant_matmul_ref(x8, w8, ws, src)
    np.testing.assert_allclose(got, want, rtol=1e-6)


# ---------------------------------------------------------------------------
# ops dispatch


def test_dense_pallas_serving_wiring():
    """layers.dense with kernel="pallas" matches the XLA dequant path — via
    the explicit argument and via the serving_mode(kernel=) ambient, which
    replaced dispatch-time reads of the USE_PALLAS_SERVING module global."""
    from repro.core.ocs import make_ocs_quant_linear
    from repro.models import layers

    rng = np.random.RandomState(11)
    w = rng.randn(96, 64).astype(np.float32)
    w[3, 5] = 9.0
    lin = make_ocs_quant_linear(w, 0.03, 8, pad_to=32)
    x = jnp.asarray(rng.randn(4, 96), jnp.float32)
    y_xla = layers.dense(lin, x)
    y_kernel = layers.dense(lin, x, kernel="pallas")
    with layers.serving_mode("dequant", kernel="pallas"):
        y_ambient = layers.dense(lin, x)
    np.testing.assert_allclose(y_xla, y_kernel, rtol=1e-4, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(y_kernel), np.asarray(y_ambient))


def test_ops_dispatch_cpu_ref():
    from repro.kernels import ops

    assert ops.backend_mode() == "ref"  # CPU container
    x8, w8 = _i8(16, 64), _i8(64, 32)
    ws = jnp.asarray(0.5, jnp.float32)
    xs = jnp.ones(16, jnp.float32)
    y = ops.quant_matmul(x8, w8, ws, xs)
    np.testing.assert_allclose(
        y, ref.quant_matmul_ref(x8, w8, xs, jnp.broadcast_to(ws, (32,))), rtol=1e-6
    )
    q, s = ops.dynamic_quant(_f(8, 128))
    assert q.dtype == jnp.int8 and s.shape == (8,)
