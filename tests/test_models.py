"""Per-architecture smoke tests (assignment deliverable).

For each of the 10 assigned architectures: instantiate the REDUCED config of
the same family, run one forward and one train step on CPU, assert output
shapes and absence of NaNs. Plus decode-path consistency checks (prefill via
full forward == step-by-step decode) for the families with a serve path.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs, smoke_config, SHAPES
from repro.models import transformer as T

ALL_ARCHS = list_archs()


def _batch(cfg, b=2, s=32):
    rng = np.random.default_rng(0)
    if cfg.frontend == "audio":
        return {
            "embeds": jnp.asarray(
                rng.normal(size=(b, s, cfg.d_model)).astype(np.float32)
            ),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab, size=(b, s))),
        }
    return {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, size=(b, s))),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, size=(b, s))),
    }


def test_all_archs_registered():
    assert len(ALL_ARCHS) == 10
    for a in ALL_ARCHS:
        cfg = get_config(a)
        assert cfg.n_layers > 0 and cfg.d_model > 0 and cfg.vocab > 0


def test_full_configs_match_assignment():
    c = get_config("hymba-1.5b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab) == (
        32, 1600, 25, 5, 5504, 32001,
    ) and c.ssm.d_state == 16
    c = get_config("deepseek-moe-16b")
    assert (c.n_layers, c.d_model, c.moe.n_experts, c.moe.top_k, c.moe.n_shared) == (
        28, 2048, 64, 6, 2,
    )
    c = get_config("phi3.5-moe-42b-a6.6b")
    assert (c.moe.n_experts, c.moe.top_k, c.d_ff) == (16, 2, 6400)
    c = get_config("glm4-9b")
    assert (c.n_layers, c.d_model, c.n_kv_heads, c.d_ff, c.vocab) == (
        40, 4096, 2, 13696, 151552,
    )
    c = get_config("minitron-8b")
    assert (c.d_ff, c.vocab) == (16384, 256000)
    c = get_config("deepseek-7b")
    assert (c.n_layers, c.n_kv_heads, c.d_ff) == (30, 32, 11008)
    c = get_config("qwen3-14b")
    assert c.qk_norm and (c.n_layers, c.d_model, c.d_ff) == (40, 5120, 17408)
    c = get_config("mamba2-1.3b")
    assert (c.n_layers, c.d_model, c.ssm.d_state) == (48, 2048, 128)
    c = get_config("qwen2-vl-7b")
    assert c.mrope_sections == (16, 24, 24) and c.d_model == 3584
    c = get_config("hubert-xlarge")
    assert not c.causal and (c.n_layers, c.d_model, c.vocab) == (48, 1280, 504)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = smoke_config(arch)
    params = T.init_params(cfg, jax.random.key(0))
    batch = _batch(cfg)
    b, s = batch["labels"].shape

    logits = T.forward(params, batch.get("tokens"), cfg, embeds=batch.get("embeds"))
    assert logits.shape == (b, s, cfg.vocab)
    assert not bool(jnp.any(jnp.isnan(logits.astype(jnp.float32))))

    loss, grads = jax.value_and_grad(lambda p: T.loss_fn(p, batch, cfg))(params)
    assert np.isfinite(float(loss))
    gnorm = jax.tree.reduce(
        lambda a, x: a + float(jnp.sum(jnp.square(x.astype(jnp.float32)))), grads, 0.0
    )
    assert np.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_decode_step(arch):
    cfg = smoke_config(arch)
    if not cfg.causal:
        pytest.skip("encoder-only: no decode step (per assignment)")
    params = T.init_params(cfg, jax.random.key(0))
    caches = T.init_cache(cfg, 2, 64)
    logits, caches = T.decode_step(params, jnp.zeros((2, 1), jnp.int32), caches, cfg)
    assert logits.shape == (2, cfg.vocab)
    assert not bool(jnp.any(jnp.isnan(logits.astype(jnp.float32))))
    assert caches["pos"].shape == (2,)  # per-slot positions
    assert np.all(np.asarray(caches["pos"]) == 1)


@pytest.mark.parametrize("arch", ["glm4-9b", "mamba2-1.3b", "qwen3-14b"])
def test_decode_matches_forward(arch):
    """Token-by-token decode must agree with the full-sequence forward."""
    cfg = smoke_config(arch)
    params = T.init_params(cfg, jax.random.key(1))
    rng = np.random.default_rng(1)
    s = 12
    toks = jnp.asarray(rng.integers(0, cfg.vocab, size=(1, s)))
    full = T.forward(params, toks, cfg).astype(jnp.float32)

    caches = T.init_cache(cfg, 1, 32, dtype=jnp.float32)
    outs = []
    for t in range(s):
        lg, caches = T.decode_step(params, toks[:, t : t + 1], caches, cfg)
        outs.append(np.asarray(lg.astype(jnp.float32)))
    dec = np.stack(outs, axis=1)  # [1, s, V]
    # bf16 compute: tolerances are loose but trends must match exactly.
    np.testing.assert_allclose(dec, np.asarray(full), rtol=0.15, atol=0.15)
    # Argmax agreement on later positions (past numerical noise).
    agree = (dec[0, 2:].argmax(-1) == np.asarray(full)[0, 2:].argmax(-1)).mean()
    assert agree >= 0.8


def test_scan_unroll_equivalence():
    cfg = smoke_config("glm4-9b")
    params = T.init_params(cfg, jax.random.key(2))
    toks = jnp.asarray(np.random.default_rng(2).integers(0, cfg.vocab, (2, 16)))
    a = T.forward(params, toks, cfg, scan=True).astype(jnp.float32)
    b = T.forward(params, toks, cfg, scan=False).astype(jnp.float32)
    # bf16 compute: scan and unrolled layouts accumulate in different orders.
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=0.05, atol=0.05)


def test_chunked_attention_matches_direct():
    """Online-softmax chunked attention == direct softmax attention."""
    import dataclasses
    from repro.models.attention import attention, attention_params_shape
    from repro.models import transformer as TT

    cfg = dataclasses.replace(smoke_config("glm4-9b"), attn_chunk=8)
    cfg2 = dataclasses.replace(cfg, attn_chunk=64)  # one chunk = direct-ish
    rng = np.random.default_rng(3)
    p = {
        k: jnp.asarray(rng.normal(size=s).astype(np.float32)) * 0.1
        for k, s in attention_params_shape(cfg).items()
    }
    x = jnp.asarray(rng.normal(size=(2, 64, cfg.d_model)).astype(np.float32))
    pos = jnp.broadcast_to(jnp.arange(64)[None], (2, 64))
    y1 = attention(p, x, cfg, positions=pos)
    y2 = attention(p, x, cfg2, positions=pos)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-3, atol=2e-3)


def test_moe_all_tokens_routed():
    """With ample capacity no token should be dropped (combine sums gates=1)."""
    from repro.models.moe import moe, moe_params_shape
    import dataclasses

    cfg = smoke_config("deepseek-moe-16b")
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0, n_shared=0)
    )
    rng = np.random.default_rng(4)
    shapes = moe_params_shape(cfg)
    p = jax.tree.map(
        lambda s: jnp.asarray(rng.normal(size=s).astype(np.float32)) * 0.05,
        shapes,
        is_leaf=lambda s: isinstance(s, tuple),
    )
    x = jnp.asarray(rng.normal(size=(2, 8, cfg.d_model)).astype(np.float32))
    y = moe(p, x, cfg)
    assert y.shape == x.shape
    assert not bool(jnp.any(jnp.isnan(y)))
    # Identity experts check: if all experts compute ~0 (tiny weights), output ~0
    # is fine; the real invariant is shape + finiteness + gradient flow.
    g = jax.grad(lambda pp: jnp.sum(moe(pp, x, cfg) ** 2))(p)
    assert float(jnp.abs(g["router"]).sum()) > 0


def test_mamba2_state_decode_matches_chunked():
    """SSD chunked scan == sequential O(1) state updates (same recurrence)."""
    import dataclasses
    from repro.models import ssm as S

    cfg = smoke_config("mamba2-1.3b")
    rng = np.random.default_rng(5)
    shapes = S.ssm_params_shape(cfg)
    p = {}
    for k, sh in shapes.items():
        if k == "A_log":
            p[k] = jnp.asarray(np.log(rng.uniform(1, 4, size=sh)).astype(np.float32))
        elif k in ("dt_bias", "conv_b"):
            p[k] = jnp.zeros(sh, jnp.float32)
        elif k in ("D", "norm_scale"):
            p[k] = jnp.ones(sh, jnp.float32)
        else:
            p[k] = jnp.asarray(rng.normal(size=sh).astype(np.float32)) * 0.2
    s_len = 24
    u = jnp.asarray(rng.normal(size=(1, s_len, cfg.d_model)).astype(np.float32))
    y_full = S.mamba2(p, u, cfg)
    cache = S.init_ssm_cache(cfg, 1, dtype=jnp.float32)
    ys = []
    for t in range(s_len):
        y_t, cache = S.mamba2_decode(p, u[:, t : t + 1], cache, cfg)
        ys.append(np.asarray(y_t)[:, 0])
    y_seq = np.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_full), y_seq, rtol=2e-2, atol=2e-2)
