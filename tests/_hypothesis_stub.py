"""Deterministic mini-implementation of the ``hypothesis`` API surface the
test suite uses, installed by ``conftest.py`` only when the real package is
absent (the serving container ships without it).

Coverage is intentionally minimal: ``given`` (positional + keyword
strategies), ``settings(max_examples, deadline)``, and the ``integers`` /
``floats`` / ``lists`` strategies. Draws are seeded per test name so runs are
reproducible; each strategy yields its boundary values first (the cases
hypothesis shrinks toward) before random interior draws.
"""
from __future__ import annotations

import zlib

import numpy as np


class _Strategy:
    def boundaries(self):
        return []

    def draw(self, rng):
        raise NotImplementedError


class _Integers(_Strategy):
    def __init__(self, min_value, max_value):
        self.lo = int(min_value)
        self.hi = int(max_value)

    def boundaries(self):
        vals = {self.lo, self.hi}
        if self.lo <= 0 <= self.hi:
            vals.add(0)
        return sorted(vals)

    def draw(self, rng):
        return int(rng.integers(self.lo, self.hi + 1))


class _Floats(_Strategy):
    def __init__(self, min_value=-1e6, max_value=1e6, allow_nan=False, **_kw):
        self.lo = float(min_value)
        self.hi = float(max_value)

    def boundaries(self):
        vals = [self.lo, self.hi]
        if self.lo <= 0.0 <= self.hi:
            vals.append(0.0)
        return vals

    def draw(self, rng):
        return float(rng.uniform(self.lo, self.hi))


class _Lists(_Strategy):
    def __init__(self, elements, min_size=0, max_size=10, **_kw):
        self.elements = elements
        self.min_size = int(min_size)
        self.max_size = int(max_size)

    def boundaries(self):
        out = []
        for b in self.elements.boundaries():
            out.append([b] * max(self.min_size, 1))
        return out

    def draw(self, rng):
        n = int(rng.integers(self.min_size, self.max_size + 1))
        return [self.elements.draw(rng) for _ in range(n)]


class strategies:  # mirrors `from hypothesis import strategies as st`
    @staticmethod
    def integers(min_value=0, max_value=100):
        return _Integers(min_value, max_value)

    @staticmethod
    def floats(min_value=-1e6, max_value=1e6, **kw):
        return _Floats(min_value, max_value, **kw)

    @staticmethod
    def lists(elements, **kw):
        return _Lists(elements, **kw)


def settings(max_examples=20, deadline=None, **_kw):
    def deco(f):
        f._stub_max_examples = max_examples
        return f

    return deco


def given(*arg_strats, **kw_strats):
    def deco(f):
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_stub_max_examples", 20)
            rng = np.random.default_rng(zlib.crc32(f.__name__.encode()))
            cases = []
            # Boundary sweep: each strategy's edge values with the others at
            # a fixed draw — cheap coverage of the corners hypothesis finds.
            strats = list(arg_strats) + list(kw_strats.values())
            for si, s in enumerate(strats):
                for b in s.boundaries():
                    base = [t.draw(rng) for t in strats]
                    base[si] = b
                    cases.append(base)
            while len(cases) < n:
                cases.append([s.draw(rng) for s in strats])
            for case in cases[: max(n, len(cases))]:
                pos = case[: len(arg_strats)]
                kw = dict(zip(kw_strats.keys(), case[len(arg_strats) :]))
                f(*args, *pos, **kwargs, **kw)

        # NOT functools.wraps: copying __wrapped__ would make pytest inspect
        # the original signature and demand the strategy params as fixtures.
        wrapper.__name__ = f.__name__
        wrapper.__doc__ = f.__doc__
        wrapper.__module__ = f.__module__
        return wrapper

    return deco
