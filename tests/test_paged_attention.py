"""Paged-attention kernel (ISSUE 4): fused append + in-pool flash decode.

The contracts under test:

* **parity** — the Pallas kernel (interpret mode) and the gather-free XLA
  fallback match the gather-everything oracle across page sizes, ragged
  per-lane positions, and Q > 1 verify masks (float pages to float
  tolerance — online vs one-shot softmax ordering — int8 pages to
  quantization tolerance);
* **append fusion** — the pool returned by the fused dispatch is *bitwise*
  the pool `kv_cache.append_tokens` would have produced (one quant grid for
  every pool writer);
* **trash-page invariant** — page 0 poisoned with NaN changes no active
  lane's output, for the legacy gather path (the new `gather_pages` mask),
  the XLA fallback, and the interpreted kernel;
* **engine integration** — `EngineConfig.kernels.attn` selections produce
  token-identical greedy output, spec-decode output identity holds with the
  kernel enabled, `stats()` reports the attention path in the shared
  `KernelChoice` vocabulary, and the deprecated `USE_PALLAS_PAGED_ATTN`
  module flag seeds the `auto` default at engine construction only.
"""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import smoke_config
from repro.kernels import ops
from repro.kernels import paged_attention as pa
from repro.models import attention as attn_mod
from repro.models import transformer as T
from repro.serving import (EngineConfig, KernelConfig, Request,
                           ServingEngine, SpecConfig)
from repro.serving import kv_cache as kvc


def _mk_pool(rng, int8, P, KV, ps, hd):
    if int8:
        return {
            "k": jnp.asarray(rng.randint(-127, 128, (P, KV, ps, hd)), jnp.int8),
            "v": jnp.asarray(rng.randint(-127, 128, (P, KV, ps, hd)), jnp.int8),
            "k_scale": jnp.asarray(rng.rand(P, KV, ps) * 0.1 + 0.01, jnp.float32),
            "v_scale": jnp.asarray(rng.rand(P, KV, ps) * 0.1 + 0.01, jnp.float32),
        }
    return {
        "k": jnp.asarray(rng.randn(P, KV, ps, hd), jnp.float32),
        "v": jnp.asarray(rng.randn(P, KV, ps, hd), jnp.float32),
    }


def _mk_case(rng, int8, qn, ps, B=3, T=4, KV=2, rep=2, hd=16):
    """Ragged lanes: lane b owns b+2 pages (capped at T), the rest trash."""
    P = B * T + 1
    H = KV * rep
    pool = _mk_pool(rng, int8, P, KV, ps, hd)
    table = np.full((B, T), kvc.TRASH_PAGE, np.int32)
    pages = iter(range(1, P))
    pos = []
    for b in range(B):
        npg = min(T, b + 2)
        for t in range(npg):
            table[b, t] = next(pages)
        pos.append(max((npg - 1) * ps - qn - b, 0))
    args = (
        pool,
        jnp.asarray(table),
        jnp.asarray(pos, jnp.int32),
        jnp.asarray(rng.randn(B, qn, H, hd), jnp.float32),  # q
        jnp.asarray(rng.randn(B, qn, KV, hd), jnp.float32),  # k_new
        jnp.asarray(rng.randn(B, qn, KV, hd), jnp.float32),  # v_new
    )
    return args


# ---------------------------------------------------------------------------
# Kernel / fallback vs the gather oracle (op level)


@pytest.mark.parametrize("ps", [8, 16, 64])
@pytest.mark.parametrize("qn", [1, 4])
@pytest.mark.parametrize("int8", [False, True])
def test_kernel_and_xla_match_gather_oracle(ps, qn, int8):
    rng = np.random.RandomState(hash((ps, qn, int8)) % (2**31))
    args = _mk_case(rng, int8, qn, ps)
    o_ref, p_ref = ops.paged_attention(*args, force="gather")
    o_xla, p_xla = ops.paged_attention(*args, force="ref")
    o_krn, p_krn = ops.paged_attention(*args, force="interpret")
    # Float pages: same f32 math, online vs one-shot softmax ordering only.
    # Int8: the kernel dequantizes in VMEM (f32 dots, tight vs the oracle);
    # the XLA fallback runs the legacy integer path (q and softmax weights
    # requantized), so it carries the int8 cache's quantization-noise
    # tolerance (same class as tests/test_kv_cache_quant.py).
    if not int8:
        np.testing.assert_allclose(np.asarray(o_krn), np.asarray(o_ref),
                                   atol=2e-6, rtol=2e-6)
        np.testing.assert_allclose(np.asarray(o_xla), np.asarray(o_ref),
                                   atol=2e-6, rtol=2e-6)
    else:
        ref = np.asarray(o_ref)
        scale = np.abs(ref).max()
        assert np.abs(np.asarray(o_krn) - ref).max() / scale < 0.02
        assert np.abs(np.asarray(o_xla) - ref).max() / scale < 0.15
    # The appended pools must agree BITWISE across all three paths.
    for key in p_ref:
        assert (np.asarray(p_ref[key]) == np.asarray(p_xla[key])).all(), key
        assert (np.asarray(p_ref[key]) == np.asarray(p_krn[key])).all(), key


@pytest.mark.parametrize("int8", [False, True])
def test_append_fusion_matches_append_tokens(int8):
    """The fused dispatch's pool == kv_cache.append_tokens' pool, bitwise:
    one quantization grid for every pool writer."""
    rng = np.random.RandomState(7)
    pool, table, pos, q, k_new, v_new = _mk_case(rng, int8, 4, 16)
    _, p_fused = ops.paged_attention(pool, table, pos, q, k_new, v_new,
                                     force="ref")
    # append_tokens takes [B, Q, KV, hd] and the same clamp semantics. Jit
    # it like the dispatch is: eager XLA may order the absmax reduction
    # differently and flip last-ulp scale bits on ties.
    p_ref = jax.jit(kvc.append_tokens)(pool, k_new, v_new, table, pos)
    for key in p_ref:
        assert (np.asarray(p_fused[key]) == np.asarray(p_ref[key])).all(), key


@pytest.mark.parametrize("qn", [1, 4])
def test_ragged_lanes_match_solo(qn):
    """Each lane of a ragged batch gets exactly its solo-run output (the
    per-lane position bounds in the kernel are per-lane, not batch-max)."""
    rng = np.random.RandomState(3)
    pool, table, pos, q, k_new, v_new = _mk_case(rng, False, qn, 8)
    out, _ = ops.paged_attention(pool, table, pos, q, k_new, v_new,
                                 force="interpret")
    for b in range(table.shape[0]):
        solo, _ = ops.paged_attention(
            pool, table[b : b + 1], pos[b : b + 1], q[b : b + 1],
            k_new[b : b + 1], v_new[b : b + 1], force="interpret",
        )
        np.testing.assert_allclose(
            np.asarray(out[b]), np.asarray(solo[0]), atol=1e-6, rtol=1e-6
        )


@pytest.mark.parametrize("int8", [False, True])
def test_inactive_lane_outputs_zero_on_every_path(int8):
    """A retired lane (all-trash table row, pos 0) must emit exact zeros on
    all three paths — the engine never commits it, but op-level parity (and
    any batch-wide comparison) relies on the agreement."""
    rng = np.random.RandomState(13)
    pool, table, pos, q, k_new, v_new = _mk_case(rng, int8, 2, 8)
    table = table.at[1].set(kvc.TRASH_PAGE)  # retire lane 1
    pos = pos.at[1].set(0)
    for force in ("gather", "ref", "interpret"):
        out, _ = ops.paged_attention(pool, table, pos, q, k_new, v_new,
                                     force=force)
        assert (np.asarray(out[1]) == 0).all(), force


def test_q4_rows_equal_sequential_q1():
    """Per-token causal masks: the Q=4 verify shape reproduces 4 sequential
    Q=1 appends+attends (the spec-decode verify contract, at op level)."""
    rng = np.random.RandomState(11)
    pool, table, pos, q, k_new, v_new = _mk_case(rng, False, 4, 16)
    out4, pool4 = ops.paged_attention(pool, table, pos, q, k_new, v_new,
                                      force="interpret")
    cur = pool
    for j in range(4):
        oj, cur = ops.paged_attention(
            cur, table, pos + j, q[:, j : j + 1], k_new[:, j : j + 1],
            v_new[:, j : j + 1], force="interpret",
        )
        np.testing.assert_allclose(
            np.asarray(out4[:, j]), np.asarray(oj[:, 0]), atol=1e-5, rtol=1e-5
        )
    for key in cur:
        assert (np.asarray(cur[key]) == np.asarray(pool4[key])).all(), key


# ---------------------------------------------------------------------------
# Trash-page invariant: page 0 poisoned with NaN changes nothing


def _poison(pool):
    out = dict(pool)
    if pool["k"].dtype == jnp.int8:
        # int8 values can't be NaN; poison the scales instead.
        out["k_scale"] = pool["k_scale"].at[kvc.TRASH_PAGE].set(jnp.nan)
        out["v_scale"] = pool["v_scale"].at[kvc.TRASH_PAGE].set(jnp.nan)
    else:
        out["k"] = pool["k"].at[kvc.TRASH_PAGE].set(jnp.nan)
        out["v"] = pool["v"].at[kvc.TRASH_PAGE].set(jnp.nan)
    return out


@pytest.mark.parametrize("int8", [False, True])
def test_gather_pages_masks_trash(int8):
    rng = np.random.RandomState(5)
    pool, table, pos, *_ = _mk_case(rng, int8, 1, 8)
    k, v, ks, vs = kvc.gather_pages(_poison(pool), table)
    trash = np.repeat(np.asarray(table) == kvc.TRASH_PAGE, 8, axis=1)
    for arr in (k, v) + ((ks, vs) if int8 else ()):
        a = np.asarray(arr, np.float32)
        assert np.isfinite(a).all()
        # trash positions read as exact zeros, real positions untouched
        sl = a[:, :, :, 0] if arr.ndim == 4 else a
        assert (sl[np.nonzero(trash)[0], :, np.nonzero(trash)[1]] == 0).all()


@pytest.mark.parametrize("force", ["gather", "ref", "interpret"])
@pytest.mark.parametrize("int8", [False, True])
def test_nan_poisoned_trash_page_does_not_reach_outputs(force, int8):
    rng = np.random.RandomState(9)
    pool, table, pos, q, k_new, v_new = _mk_case(rng, int8, 2, 8)
    clean, _ = ops.paged_attention(pool, table, pos, q, k_new, v_new,
                                   force=force)
    dirty, _ = ops.paged_attention(_poison(pool), table, pos, q, k_new,
                                   v_new, force=force)
    # Every lane in _mk_case is active (owns real pages): outputs must be
    # finite and unchanged by the poison.
    assert np.isfinite(np.asarray(dirty)).all()
    np.testing.assert_array_equal(np.asarray(clean), np.asarray(dirty))


def test_legacy_decode_path_survives_poisoned_trash_page():
    """End to end through attention_decode's *gather* path: an active lane
    decodes next to a retired (all-trash) lane whose page 0 holds NaN."""
    cfg = smoke_config("deepseek-7b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    B, L, ps = 2, 32, 8
    t = L // ps
    caches = kvc.init_paged_cache(cfg, B, B * t + 1, ps, t, dtype=jnp.float32)
    table = np.full((B, t), kvc.TRASH_PAGE, np.int32)
    table[0] = np.arange(1, t + 1)  # lane 0 active, lane 1 retired
    caches["table"] = jnp.asarray(table)
    tok = jnp.asarray([[3], [0]], jnp.int32)

    def run(poison):
        c = jax.tree.map(lambda a: a, caches)
        if poison:
            c["layers"] = [
                {"attn": _poison(layer["attn"])} for layer in c["layers"]
            ]
        outs = []
        for _ in range(3):
            lg, c = T.decode_step(params, tok, c, cfg)
            outs.append(np.asarray(lg[0]))
        return np.stack(outs)

    clean, dirty = run(False), run(True)
    assert np.isfinite(dirty).all()
    np.testing.assert_array_equal(clean, dirty)


# ---------------------------------------------------------------------------
# Engine integration


@pytest.fixture(scope="module")
def dense_setup():
    cfg = smoke_config("glm4-9b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _run_engine(cfg, params, *, seed=0, max_new=6, attn="gather", spec_k=0,
                attn_probe=False):
    rng = np.random.default_rng(seed)
    ecfg = EngineConfig(
        max_batch=2, max_len=64, kernels=KernelConfig(attn=attn),
        spec=SpecConfig(k=spec_k) if spec_k else None, attn_probe=attn_probe,
    )
    eng = ServingEngine(cfg, params, ecfg)
    for i, n in enumerate([5, 11, 3, 17]):
        eng.submit(Request(uid=i, prompt=rng.integers(0, cfg.vocab, n).tolist(),
                           max_new_tokens=max_new))
    eng.run()
    return eng, {r.uid: r.output for r in eng.done}


def test_engine_outputs_identical_with_kernel_enabled(dense_setup):
    cfg, params = dense_setup
    _, base = _run_engine(cfg, params, attn="gather")
    eng, fused = _run_engine(cfg, params, attn="pallas")
    assert fused == base
    assert eng.paged_attn is True  # legacy view of the kernel selection


@pytest.mark.parametrize("kv_bits", [None, 8])
def test_spec_decode_output_identity_with_kernel_enabled(kv_bits):
    """The spec-decode greedy exactness contract, re-run with the paged-
    attention kernel path enabled: spec == plain, both through the kernel."""
    cfg = dataclasses.replace(smoke_config("deepseek-7b"), kv_bits=kv_bits)
    params = T.init_params(cfg, jax.random.PRNGKey(1))
    _, plain = _run_engine(cfg, params, attn="pallas")
    eng, spec = _run_engine(cfg, params, attn="pallas", spec_k=3)
    assert spec == plain
    assert eng.stats()["spec_rounds"] > 0


def test_module_flag_seeds_engine_config_default(dense_setup):
    """The deprecated USE_PALLAS_PAGED_ATTN shim seeds KernelChoice.AUTO at
    engine construction — and ONLY there: an engine built while the flag was
    set keeps its resolved kernel after the flag is restored."""
    cfg, params = dense_setup
    old = attn_mod.USE_PALLAS_PAGED_ATTN
    attn_mod.USE_PALLAS_PAGED_ATTN = True
    try:
        eng = ServingEngine(cfg, params, EngineConfig(max_batch=1, max_len=32))
        assert eng.attn_kernel == "pallas" and eng.paged_attn is True
    finally:
        attn_mod.USE_PALLAS_PAGED_ATTN = old
    # Construction-time seeding only: the engine keeps "pallas" ...
    assert eng.attn_kernel == "pallas"
    # ... and a fresh default engine resolves the restored flag to "gather".
    eng2 = ServingEngine(cfg, params, EngineConfig(max_batch=1, max_len=32))
    assert eng2.attn_kernel == "gather" and eng2.paged_attn is False


def test_stats_report_attention_path(dense_setup):
    cfg, params = dense_setup
    eng, _ = _run_engine(cfg, params, attn="pallas", attn_probe=True)
    s = eng.stats()
    assert s["attn_kernel"] in ("pallas", "xla")
    if jax.default_backend() != "tpu":
        assert s["attn_kernel"] == "xla"  # kernel can't compile off-TPU
    assert s["attn_step_ms"] > 0.0  # probe enabled
    eng2, _ = _run_engine(cfg, params)
    assert eng2.stats()["attn_step_ms"] == 0.0  # probe off by default
    assert eng2.stats()["attn_kernel"] == "gather"  # KernelChoice vocabulary
