"""Unit + property tests for the linear symmetric quantizer (paper Eq. 1)."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import quantizer as Q


def test_qmax_values():
    assert Q.qmax(8) == 127
    assert Q.qmax(4) == 7
    assert Q.qmax(2) == 1
    with pytest.raises(ValueError):
        Q.qmax(1)


def test_storage_dtype():
    assert Q.storage_dtype(8) == jnp.int8
    assert Q.storage_dtype(4) == jnp.int8
    assert Q.storage_dtype(16) == jnp.int16


def test_eq1_matches_paper_formula():
    """Bit-exact check of Eq. 1: round(x*(2^(k-1)-1)/max|x|) * max|x|/(2^(k-1)-1)."""
    rng = np.random.default_rng(0)
    x = rng.normal(size=(64, 32)).astype(np.float32)
    for k in (8, 6, 4):
        got = np.asarray(Q.fake_quant(jnp.asarray(x), k))
        m = np.abs(x).max()
        want = np.floor(x * (2 ** (k - 1) - 1) / m + 0.5) * m / (2 ** (k - 1) - 1)
        np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-7)


def test_int_and_fake_paths_agree():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(128,)).astype(np.float32))
    qp = Q.quantize_tensor(x, 6)
    np.testing.assert_allclose(
        np.asarray(qp.dequant()), np.asarray(Q.fake_quant(x, 6)), atol=1e-7
    )


def test_per_channel_scales():
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(16, 8)).astype(np.float32) * np.arange(1, 9))
    qp = Q.quantize_tensor(x, 8, channel_axis=1)
    assert qp.scale.shape == (8,)
    # Per-channel must be at least as accurate as per-tensor on scaled channels.
    err_pc = float(jnp.mean((qp.dequant() - x) ** 2))
    err_pt = float(jnp.mean((Q.fake_quant(x, 8) - x) ** 2))
    assert err_pc <= err_pt + 1e-12


def test_clip_saturates():
    x = jnp.asarray([0.1, 0.5, 2.0, -3.0], dtype=jnp.float32)
    y = np.asarray(Q.fake_quant(x, 8, clip=1.0))
    assert y.max() <= 1.0 + 1e-6 and y.min() >= -1.0 - 1e-6


def test_zero_tensor_safe():
    x = jnp.zeros((4, 4))
    y = Q.fake_quant(x, 8)
    assert np.all(np.asarray(y) == 0)


@settings(max_examples=200, deadline=None)
@given(
    st.lists(
        st.floats(min_value=-1e4, max_value=1e4, allow_nan=False),
        min_size=1,
        max_size=64,
    ),
    st.integers(min_value=2, max_value=8),
)
def test_quantization_error_bound(vals, bits):
    """Property: per-element error <= step/2 for in-range values (paper §3.1)."""
    x = jnp.asarray(np.array(vals, dtype=np.float32))
    y = Q.fake_quant(x, bits)
    m = float(jnp.max(jnp.abs(x)))
    if m == 0:
        return
    step = m / Q.qmax(bits)
    assert float(jnp.max(jnp.abs(y - x))) <= step / 2 + 1e-4 * step


@settings(max_examples=100, deadline=None)
@given(
    st.lists(
        st.floats(min_value=-100, max_value=100, allow_nan=False),
        min_size=2,
        max_size=32,
    ),
    st.integers(min_value=2, max_value=8),
)
def test_idempotence(vals, bits):
    """Property: quantizing an already-quantized tensor is the identity."""
    x = jnp.asarray(np.array(vals, dtype=np.float32))
    y1 = Q.fake_quant(x, bits)
    m = float(jnp.max(jnp.abs(x)))
    if m == 0:
        return
    y2 = Q.fake_quant(y1, bits, clip=m)  # same grid
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y1), rtol=1e-5, atol=1e-6)
