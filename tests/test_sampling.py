"""Per-request sampling + cancellation (ISSUE 5).

The contracts under test:

* **greedy is untouched** — temperature 0 (and the top-k=1 / tiny-top-p /
  tiny-temperature limits) reproduce the exact argmax stream, so every
  PR-1..4 bit-exactness contract survives the sampling fold-in;
* **determinism** — fixed-seed sampling is bit-reproducible across runs,
  across batch compositions (a sampled request draws the same tokens solo
  or batched), and identical between paged and unpaged engines (the PRNG
  key is a function of (seed, position) only; float pages give bit-exact
  logits);
* **spec fallback** — lanes with non-greedy params fall back to plain
  decode on speculative engines this PR; greedy-only workloads still
  speculate;
* **cancellation** — cancel mid-decode reclaims exactly the lane's pages:
  allocator-state parity vs never having submitted the request, for paged
  and unpaged engines, dense and MoE.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import smoke_config
from repro.models import transformer as T
from repro.serving import (
    EngineConfig,
    Request,
    SamplingParams,
    ServingEngine,
    SpecConfig,
)
from repro.serving.sampling import greedy_sampling_arrays, sample_tokens


@pytest.fixture(scope="module")
def dense_setup():
    cfg = smoke_config("glm4-9b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _serve(cfg, params, reqs, **cfg_kw):
    eng = ServingEngine(cfg, params, EngineConfig(**cfg_kw))
    for r in reqs:
        eng.submit(r)
    eng.run()
    return eng, {r.uid: r.output for r in eng.done}


def _reqs(rng, vocab, lengths, max_new=6, sampling=None, eos=None):
    return [
        Request(uid=i, prompt=rng.integers(0, vocab, n).tolist(),
                max_new_tokens=max_new, eos_id=eos, sampling=sampling)
        for i, n in enumerate(lengths)
    ]


# ---------------------------------------------------------------------------
# sample_tokens unit: the degenerate limits all reproduce argmax


def _unit_case(b=4, v=64, seed=0):
    rng = np.random.RandomState(seed)
    logits = jnp.asarray(rng.randn(b, v) * 3, jnp.float32)
    pos = jnp.asarray(rng.randint(1, 50, b), jnp.int32)
    return logits, pos, np.argmax(np.asarray(logits), -1)


def _samp(b, **kw):
    s = greedy_sampling_arrays(b)
    for k, val in kw.items():
        s[k] = jnp.full_like(s[k], val)
    return s


def test_sample_tokens_degenerate_limits_equal_argmax():
    logits, pos, argmax = _unit_case()
    b = logits.shape[0]
    # temperature == 0: the exact greedy branch.
    np.testing.assert_array_equal(
        np.asarray(sample_tokens(logits, _samp(b), pos)), argmax)
    # top_k == 1: only the argmax survives the mask.
    np.testing.assert_array_equal(
        np.asarray(sample_tokens(
            logits, _samp(b, temperature=1.0, top_k=1), pos)), argmax)
    # top_p -> 0: the nucleus keeps only the top token.
    np.testing.assert_array_equal(
        np.asarray(sample_tokens(
            logits, _samp(b, temperature=1.0, top_p=1e-9), pos)), argmax)
    # temperature -> 0: the scaled gap dwarfs the Gumbel noise.
    np.testing.assert_array_equal(
        np.asarray(sample_tokens(
            logits, _samp(b, temperature=1e-4), pos)), argmax)


def test_sample_tokens_respects_top_k_support():
    """Sampled tokens always come from the top-k set, across many keys."""
    logits, pos, _ = _unit_case(b=3, v=32, seed=1)
    top4 = np.argsort(np.asarray(logits), -1)[:, -4:]
    for p0 in range(20):
        toks = np.asarray(sample_tokens(
            logits, _samp(3, temperature=2.0, top_k=4), pos + p0))
        for b in range(3):
            assert toks[b] in top4[b], (b, p0)


def test_sample_tokens_mixed_lanes_keep_greedy_exact():
    logits, pos, argmax = _unit_case(b=4)
    s = greedy_sampling_arrays(4)
    s["temperature"] = jnp.asarray([0.0, 1.5, 0.0, 0.7], jnp.float32)
    s["seed"] = jnp.asarray([0, 9, 0, 9], jnp.uint32)
    toks = np.asarray(sample_tokens(logits, s, pos))
    assert toks[0] == argmax[0] and toks[2] == argmax[2]


# ---------------------------------------------------------------------------
# Engine level: reproducibility and paged/unpaged identity


@pytest.mark.parametrize("matmul_mode", ["dequant", "w8a8"])
def test_fixed_seed_bit_reproducible_and_paged_matches_unpaged(
    dense_setup, matmul_mode
):
    cfg, params = dense_setup
    sp = SamplingParams(temperature=0.9, top_k=50, top_p=0.95, seed=123)

    def run(paged):
        rng = np.random.default_rng(11)
        _, out = _serve(cfg, params, _reqs(rng, cfg.vocab, [5, 11, 3], 6, sp),
                        max_batch=2, max_len=64, paged=paged,
                        matmul_mode=matmul_mode)
        return out

    a, b = run(True), run(True)
    assert a == b, "fixed-seed sampling must be bit-reproducible"
    assert run(False) == a, "paged and unpaged engines must sample identically"


def test_sampled_request_identical_solo_or_batched(dense_setup):
    """The PRNG key depends on (seed, position) only — batch composition
    and lane index cannot change a request's sampled stream."""
    cfg, params = dense_setup
    rng = np.random.default_rng(7)
    prompt = rng.integers(0, cfg.vocab, 6).tolist()
    sp = SamplingParams(temperature=1.1, top_k=0, top_p=0.9, seed=5)

    _, solo = _serve(
        cfg, params,
        [Request(uid=0, prompt=list(prompt), max_new_tokens=5, sampling=sp)],
        max_batch=1, max_len=64,
    )
    neighbours = _reqs(np.random.default_rng(8), cfg.vocab, [4, 9], 5,
                       SamplingParams(temperature=0.8, seed=99))
    for i, r in enumerate(neighbours):
        r.uid = 10 + i
    _, batched = _serve(
        cfg, params,
        [Request(uid=0, prompt=list(prompt), max_new_tokens=5, sampling=sp)]
        + neighbours,
        max_batch=3, max_len=64,
    )
    assert batched[0] == solo[0]


def test_temperature_to_zero_converges_to_greedy(dense_setup):
    cfg, params = dense_setup
    rng = np.random.default_rng(2)
    lengths = [5, 9]
    _, greedy = _serve(cfg, params,
                       _reqs(np.random.default_rng(2), cfg.vocab, lengths),
                       max_batch=2, max_len=64)
    for temp in (0.0, 1e-4):
        sp = SamplingParams(temperature=temp, seed=7)
        _, out = _serve(cfg, params,
                        _reqs(np.random.default_rng(2), cfg.vocab, lengths,
                              sampling=sp),
                        max_batch=2, max_len=64)
        assert out == greedy, f"temperature={temp} must reproduce argmax"


def test_mixed_batch_greedy_lane_is_exact(dense_setup):
    """A greedy request surrounded by sampled neighbours emits exactly its
    solo-greedy stream (the sampling fold-in cannot perturb greedy lanes)."""
    cfg, params = dense_setup
    rng = np.random.default_rng(13)
    gprompt = rng.integers(0, cfg.vocab, 7).tolist()
    _, solo = _serve(
        cfg, params,
        [Request(uid=0, prompt=list(gprompt), max_new_tokens=6)],
        max_batch=1, max_len=64,
    )
    sp = SamplingParams(temperature=1.3, seed=3)
    mixed = [Request(uid=0, prompt=list(gprompt), max_new_tokens=6)]
    mixed += [
        Request(uid=1 + i, prompt=rng.integers(0, cfg.vocab, 5).tolist(),
                max_new_tokens=6, sampling=sp)
        for i in range(2)
    ]
    _, out = _serve(cfg, params, mixed, max_batch=3, max_len=64)
    assert out[0] == solo[0]


# ---------------------------------------------------------------------------
# Spec engines: sampled lanes fall back to plain decode (this PR)


def test_spec_engine_sampled_fallback_matches_plain(dense_setup):
    cfg, params = dense_setup
    sp = SamplingParams(temperature=0.8, top_k=30, seed=21)

    def run(spec):
        rng = np.random.default_rng(4)
        return _serve(cfg, params, _reqs(rng, cfg.vocab, [5, 8], 5, sp),
                      max_batch=2, max_len=32, spec=spec)

    _, plain = run(None)
    eng, specd = run(SpecConfig(k=3))
    assert specd == plain  # the fallback is the ordinary sampled decode
    assert eng.stats()["spec_rounds"] == 0  # no round speculated


def test_spec_engine_still_speculates_greedy_workloads(dense_setup):
    cfg, params = dense_setup
    rng = np.random.default_rng(6)
    reqs = _reqs(rng, cfg.vocab, [5, 9], 6)
    eng, out = _serve(cfg, params, reqs, max_batch=2, max_len=32,
                      spec=SpecConfig(k=2))
    rng = np.random.default_rng(6)
    _, plain = _serve(cfg, params, _reqs(rng, cfg.vocab, [5, 9], 6),
                      max_batch=2, max_len=32)
    assert out == plain
    assert eng.stats()["spec_rounds"] > 0


def test_spec_engine_mixed_greedy_sampled_batch(dense_setup):
    """Greedy requests keep their exact stream even when a sampled
    neighbour forces plain-decode rounds mid-flight."""
    cfg, params = dense_setup
    rng = np.random.default_rng(9)
    gprompt = rng.integers(0, cfg.vocab, 6).tolist()
    _, solo = _serve(cfg, params,
                     [Request(uid=0, prompt=list(gprompt), max_new_tokens=6)],
                     max_batch=1, max_len=32, spec=SpecConfig(k=2))
    mixed = [
        Request(uid=0, prompt=list(gprompt), max_new_tokens=6),
        Request(uid=1, prompt=rng.integers(0, cfg.vocab, 4).tolist(),
                max_new_tokens=3,
                sampling=SamplingParams(temperature=1.0, seed=17)),
    ]
    eng, out = _serve(cfg, params, mixed, max_batch=2, max_len=32,
                      spec=SpecConfig(k=2))
    assert out[0] == solo[0]
    # The sampled lane retired mid-run, after which greedy rounds speculate.
    assert eng.stats()["spec_rounds"] > 0


# ---------------------------------------------------------------------------
# Cancellation: allocator-state parity vs never-submitted


def _alloc_state(eng):
    a = eng.allocator
    return (a.in_use(), a.available(), a.cached_pages())


@pytest.mark.parametrize("arch", ["glm4-9b", "deepseek-moe-16b"])
@pytest.mark.parametrize("paged", [True, False])
def test_cancel_mid_decode_reclaims_lane(arch, paged):
    cfg = smoke_config(arch)
    params = T.init_params(cfg, jax.random.PRNGKey(1))
    rng = np.random.default_rng(3)
    # Short prompts (< page_size): no full prompt pages get registered, so
    # allocator parity below is exact across every counter.
    victim = Request(uid=0, prompt=rng.integers(0, cfg.vocab, 5).tolist(),
                     max_new_tokens=40)
    other_prompt = rng.integers(0, cfg.vocab, 7).tolist()

    eng = ServingEngine(cfg, params,
                        EngineConfig(max_batch=2, max_len=64, paged=paged))
    eng.submit(victim)
    eng.submit(Request(uid=1, prompt=list(other_prompt), max_new_tokens=6))
    for _ in range(3):
        eng.step()
    assert 0 < len(victim.output) < 40  # genuinely mid-decode
    assert eng.cancel(0)
    assert victim.finish_reason == "cancelled"
    eng.run()

    ref = ServingEngine(cfg, params,
                        EngineConfig(max_batch=2, max_len=64, paged=paged))
    ref.submit(Request(uid=1, prompt=list(other_prompt), max_new_tokens=6))
    ref.run()

    # The survivor's stream is untouched by the cancelled neighbour.
    out = {r.uid: r.output for r in eng.done}
    assert out[1] == ref.done[0].output
    assert all(s.req is None for s in eng.slots)  # lane freed
    if paged:
        # Exactly the lane's pages came back: allocator state matches an
        # engine that never saw the cancelled request.
        assert _alloc_state(eng) == _alloc_state(ref)
        assert eng.stats()["kv_pages_in_use"] == 0.0
        # The cancelled lane's table row points at the trash page.
        assert (np.asarray(eng.caches["table"]) == 0).all()
    s = eng.stats()
    assert s["cancelled"] == 1 and s["completed"] == 1


def test_cancel_inside_generate_stream(dense_setup):
    """cancel() between TokenEvents ends the stream: no further tokens are
    produced, the request records "cancelled", and its pages come back."""
    cfg, params = dense_setup
    eng = ServingEngine(cfg, params, EngineConfig(max_batch=1, max_len=64))
    events = []
    uid = None
    for ev in eng.generate([1, 2, 3, 4], max_new_tokens=30):
        events.append(ev)
        uid = ev.uid
        if ev.index == 2:
            assert eng.cancel(uid)
    assert len(events) == 3  # the stream stopped right at the cancel
    cancelled = next(r for r in eng.done if r.uid == uid)
    assert cancelled.finish_reason == "cancelled"
    assert eng.stats()["kv_pages_in_use"] == 0.0
    assert eng.stats()["cancelled"] == 1
